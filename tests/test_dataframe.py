"""DataFrame correctness: a property harness running random structured
queries against a plain-Python reference evaluator across the SQS/S3 x
columnar matrix (the ISSUE-4 random-DAG pattern, lifted to the SQL
surface), plus deterministic tests for:

  * optimized == unoptimized == reference (the optimizer preserves
    semantics),
  * RDD.take(n) partial evaluation (a source task stops READING after
    its first n records; the action merge short-circuits),
  * declared-schema columnar batches (and the silent fallback when data
    outgrows the declaration),
  * adaptive transport selection on the plain-RDD path (config "auto" vs
    pinned override),
  * DataFrame.cache(), count(), cluster-backend equality.
"""

import operator
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FlintConfig, FlintContext, build_plan
from repro.sql import (Schema, avg_, col, collect_list, count_, lit, max_,
                       min_, sum_)

ADD = operator.add

TRANSIENT_PREFIXES = ("_spill/", "_payload/", "_exchange/", "_result/",
                      "_broadcast/", "_stream/")


def assert_no_leaks(ctx):
    for prefix in TRANSIENT_PREFIXES:
        assert not ctx.store.list(prefix), f"leaked {prefix} keys"
    assert ctx.last_scheduler.sqs._queues == {}, "queues leaked"


# --------------------------------------------------- random query specs
#
# A query is a base dataset plus a sequence of ops; the engine runs it as
# a DataFrame (optimized and not), the reference interprets the SAME ops
# over plain Python lists of tuples. Value columns stay integral so sums
# are arrival-order-independent across transports.

BASE_SCHEMA = Schema([("k", "int"), ("s", "str"), ("v", "int"),
                      ("w", "int")])
LETTERS = ["aa", "bb", "cc"]


def gen_query(seed: int):
    rng = random.Random(seed)
    rows = [(rng.randrange(4), rng.choice(LETTERS), rng.randrange(1, 9),
             rng.randrange(1, 5))
            for _ in range(rng.randint(6, 18))]
    ops = []
    n_ops = rng.randint(1, 3)
    for _ in range(n_ops):
        kind = rng.choice(["where", "withcol", "select", "group", "join"])
        ops.append((kind, rng.random()))
    if rng.random() < 0.5:
        ops.append(("sortlimit", rng.random()))
    return rows, ops


def _apply_ops(df, rows, schema_cols, ops, rng_rows2):
    """Build the DataFrame query AND its reference rows in lockstep.
    ``schema_cols`` tracks (name, dtype) of the current shape."""

    def names():
        return [n for n, _ in schema_cols]

    def idx(name):
        return names().index(name)

    for kind, r in ops:
        cols = names()
        int_cols = [n for n, t in schema_cols if t == "int"]
        if kind == "where" and int_cols:
            c = int_cols[int(r * len(int_cols)) % len(int_cols)]
            cut = int(r * 10) % 5
            df = df.where(col(c) > lit(cut))
            i = idx(c)
            rows = [row for row in rows if row[i] > cut]
        elif kind == "withcol" and int_cols:
            c = int_cols[int(r * 7) % len(int_cols)]
            new = f"x{len(cols)}"
            df = df.withColumn(new, col(c) * lit(2) + lit(1))
            i = idx(c)
            rows = [row + (row[i] * 2 + 1,) for row in rows]
            schema_cols = schema_cols + [(new, "int")]
        elif kind == "select":
            keep_n = max(1, int(r * len(cols)) % len(cols) or 1)
            keep = cols[:keep_n]
            df = df.select(*keep)
            ids = [idx(n) for n in keep]
            rows = [tuple(row[i] for i in ids) for row in rows]
            schema_cols = [schema_cols[i] for i in ids]
        elif kind == "group" and int_cols:
            key = cols[int(r * 3) % min(2, len(cols))]
            vcol = int_cols[int(r * 11) % len(int_cols)]
            ki = idx(key)
            vi = idx(vcol)
            use_list = r > 0.7
            aggs = [sum_(col(vcol)).alias("t"), count_().alias("n"),
                    min_(col(vcol)).alias("lo"),
                    avg_(col(vcol)).alias("m")]
            if use_list:
                aggs.append(collect_list(col(vcol)).alias("vs"))
            df = df.groupBy(key).agg(*aggs)
            groups: dict = {}
            for row in rows:
                groups.setdefault(row[ki], []).append(row[vi])
            rows = []
            for gk, vals in groups.items():
                out = (gk, sum(vals), len(vals), min(vals),
                       sum(vals) / len(vals))
                if use_list:
                    out = out + (vals,)
                rows.append(out)
            kt = schema_cols[ki][1]
            schema_cols = [(key, kt), ("t", "int"), ("n", "int"),
                           ("lo", "int"), ("m", "float")]
            if use_list:
                schema_cols.append(("vs", "list:int"))
        elif kind == "join":
            if "k" not in names() or any(t.startswith("list:")
                                         for _, t in schema_cols):
                continue
            if schema_cols[idx("k")][1] != "int":
                continue
            bname = f"bonus{len(cols)}"  # unique across repeated joins
            rows2 = [(i, rng_rows2.randrange(10))
                     for i in range(rng_rows2.randrange(2, 6))]
            df2 = (df.ctx.parallelize(rows2, 2)
                   .toDF([("k", "int"), (bname, "int")]))
            df = df.join(df2, on="k")
            ki = idx("k")
            right = {}
            for kk, b in rows2:
                right.setdefault(kk, []).append(b)
            out = []
            for row in rows:
                for b in right.get(row[ki], []):
                    rest = tuple(v for i, v in enumerate(row) if i != ki)
                    out.append((row[ki],) + rest + (b,))
            rows = out
            schema_cols = ([schema_cols[ki]]
                           + [f for i, f in enumerate(schema_cols)
                              if i != ki] + [(bname, "int")])
        elif kind == "sortlimit":
            sortable = [n for n, t in schema_cols
                        if not t.startswith("list:")]
            if not sortable:
                continue
            n = max(1, int(r * 6))
            df = df.orderBy(*sortable).limit(n)
            ids = [idx(c) for c in sortable]
            rows = sorted(rows,
                          key=lambda row: tuple(row[i] for i in ids))[:n]
            break  # final operators close the query
    return df, rows


def _norm(x):
    if isinstance(x, list):
        return sorted((_norm(v) for v in x), key=repr)
    if isinstance(x, tuple):
        return tuple(_norm(v) for v in x)
    return x


def canon(rows):
    return sorted(repr(_norm(r)) for r in rows)


def run_query_case(seed, backend, columnar, check_unoptimized=False):
    rows, ops = gen_query(seed)
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=6, shuffle_backend=backend,
                                   columnar_batches=columnar))
    df = ctx.parallelize(rows, 2).toDF(BASE_SCHEMA)
    df, expect = _apply_ops(df, rows, list(BASE_SCHEMA.fields), ops,
                            random.Random(seed ^ 0xBEEF))
    got = df.collect()
    assert canon(got) == canon(expect), f"seed {seed}: engine != reference"
    assert_no_leaks(ctx)
    if check_unoptimized:
        raw = df.collect(optimize=False)
        assert canon(raw) == canon(expect), \
            f"seed {seed}: unoptimized lowering != reference"
        assert_no_leaks(ctx)


def _make_cell_test(backend, columnar):
    @given(seed=st.integers(min_value=0, max_value=10**9))
    @settings(max_examples=25, deadline=None)
    def test(seed):
        run_query_case(seed, backend, columnar,
                       check_unoptimized=(backend == "sqs" and columnar))
    test.__name__ = (f"test_random_df_equivalence_{backend}_"
                     f"{'columnar' if columnar else 'pickle'}")
    test.__qualname__ = test.__name__
    return test


for _cell in [(b, c) for b in ("sqs", "s3") for c in (True, False)]:
    _cell_test = _make_cell_test(*_cell)
    globals()[_cell_test.__name__] = _cell_test
del _cell, _cell_test


def run_adaptive_ab_query(seed, backend, columnar):
    """The same generated query with adaptive execution ON and OFF must
    match the reference evaluator (and each other) with zero leaks."""
    rows, ops = gen_query(seed)
    for adaptive in (True, False):
        ctx = FlintContext("flint",
                           FlintConfig(concurrency=6,
                                       shuffle_backend=backend,
                                       columnar_batches=columnar,
                                       adaptive=adaptive))
        df = ctx.parallelize(rows, 2).toDF(BASE_SCHEMA)
        df, expect = _apply_ops(df, rows, list(BASE_SCHEMA.fields), ops,
                                random.Random(seed ^ 0xBEEF))
        got = df.collect()
        assert canon(got) == canon(expect), \
            f"seed {seed} adaptive={adaptive}: engine != reference"
        assert_no_leaks(ctx)


def _make_adaptive_ab_test(backend, columnar):
    @given(seed=st.integers(min_value=0, max_value=10**9))
    @settings(max_examples=25, deadline=None)
    def test(seed):
        run_adaptive_ab_query(seed, backend, columnar)
    test.__name__ = (f"test_random_df_adaptive_ab_{backend}_"
                     f"{'columnar' if columnar else 'pickle'}")
    test.__qualname__ = test.__name__
    return test


for _cell in [(b, c) for b in ("sqs", "s3") for c in (True, False)]:
    _cell_test = _make_adaptive_ab_test(*_cell)
    globals()[_cell_test.__name__] = _cell_test
del _cell, _cell_test


# ----------------------------------------------------------- RDD.take(n)


def test_take_returns_first_records_in_partition_order():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    ctx.upload("n.txt", ("\n".join(str(i) for i in range(100)) + "\n")
               .encode())
    r = ctx.textFile("n.txt", 4).map(int)
    assert r.take(5) == [0, 1, 2, 3, 4]
    assert r.take(0) == []
    assert len(r.take(500)) == 100
    assert_no_leaks(ctx)


def test_take_stops_reading_the_source_early():
    """The limit op caps how much each source task READS, not just what
    it returns: with small fetch chunks, take(3) must move far fewer
    bytes from the store than a full collect."""
    data = ("\n".join(f"line-{i:06d}" for i in range(20_000)) + "\n")
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            chunk_fetch_bytes=2048))
    ctx.upload("big.txt", data.encode())
    rdd = ctx.textFile("big.txt", 4)
    rdd.collect()
    full_read = ctx.ledger.bytes_from_s3
    ctx2 = FlintContext("flint", FlintConfig(concurrency=4,
                                             chunk_fetch_bytes=2048))
    ctx2.upload("big.txt", data.encode())
    got = ctx2.textFile("big.txt", 4).take(3)
    assert got == ["line-000000", "line-000001", "line-000002"]
    assert ctx2.ledger.bytes_from_s3 < full_read / 10, \
        (ctx2.ledger.bytes_from_s3, full_read)


def test_take_after_shuffle_and_on_cluster():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    pairs = [(i % 5, 1) for i in range(50)]
    out = ctx.parallelize(pairs, 4).reduceByKey(ADD, 3).take(2)
    assert len(out) == 2 and all(v == 10 for _, v in out)
    cc = FlintContext("cluster", FlintConfig(concurrency=4))
    assert len(cc.parallelize(pairs, 4).reduceByKey(ADD, 3).take(2)) == 2


def test_dataframe_limit_uses_merge_short_circuit():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(i, i * 2) for i in range(40)], 4)
          .toDF([("a", "int"), ("b", "int")]))
    got = df.limit(7).collect()
    assert len(got) == 7
    assert got == [(i, i * 2) for i in range(7)]  # partition order
    assert df.limit(7).count() == 7


# ----------------------------------------- adaptive transport selection


def test_auto_backend_resolves_transport_at_plan_time():
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend="auto"))
    ctx.upload("small.txt", b"1\n2\n3\n")
    small = (ctx.textFile("small.txt", 2).map(lambda x: (int(x), 1))
             .reduceByKey(ADD, 2))
    plan = build_plan(small, "collect", default_transport="auto")
    assert plan[0].write.transport == "sqs"

    ctx.upload("big.bin", b"x" * 50_000_000)
    big = (ctx.textFile("big.bin", 2).map(lambda x: (x, 1))
           .reduceByKey(ADD, 2))
    plan = build_plan(big, "collect", default_transport="auto")
    assert plan[0].write.transport == "s3"
    # ShuffleRead mirrors the resolved choice so both ends agree
    read = plan[1].tasks[0].input
    assert read.transports == {plan[0].write.shuffle_id: "s3"}


def test_pinned_backend_overrides_auto_and_hints_override_both():
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend="s3"))
    ctx.upload("small.txt", b"1\n2\n3\n")
    rdd = (ctx.textFile("small.txt", 2).map(lambda x: (int(x), 1))
           .reduceByKey(ADD, 2))
    plan = build_plan(rdd, "collect",
                      default_transport=ctx.config.shuffle_backend)
    assert plan[0].write.transport == ""  # runtime default applies
    hinted = (ctx.textFile("small.txt", 2).map(lambda x: (int(x), 1))
              .reduceByKey(ADD, 2, transport="sqs"))
    plan = build_plan(hinted, "collect", default_transport="auto")
    assert plan[0].write.transport == "sqs"


def test_auto_backend_runs_end_to_end():
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend="auto"))
    out = sorted(ctx.parallelize([(i % 3, 1) for i in range(30)], 3)
                 .reduceByKey(ADD, 2).collect())
    assert out == [(0, 10), (1, 10), (2, 10)]
    assert_no_leaks(ctx)


def test_cached_lineage_sizes_feed_the_estimate():
    """A ready cache() materialization prices the shuffle from ACTUAL
    stored batch bytes instead of the source-size heuristic."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend="auto"))
    src = ctx.parallelize([(i % 5, i) for i in range(50)], 2).cache()
    src.reduceByKey(ADD, 2).collect()  # materializes the cache
    assert ctx.store.list("_cache/")
    plan = build_plan(src.reduceByKey(ADD, 2), "collect",
                      cache_index=ctx._cache_index,
                      default_transport="auto")
    # cached bytes are tiny -> sqs; and the plan reads the cache
    assert plan[0].write.transport == "sqs"
    from repro.core.dag import CacheInput
    assert isinstance(plan[0].tasks[0].input, CacheInput)


# ------------------------------------------- declared columnar schemas


def test_lowered_shuffles_declare_batch_schemas():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(1, "a", 2)], 2)
          .toDF([("k", "int"), ("s", "str"), ("v", "int")]))
    q = df.groupBy("k").agg(sum_(col("v")).alias("t"),
                            count_().alias("n"))
    from repro.sql.lower import lower
    from repro.sql.optimizer import optimize
    rdd, _, _ = lower(optimize(q.plan, ctx), ctx)
    plan = build_plan(rdd, "collect")
    write = plan[0].write
    assert write.batch_schema == ("t(i)", "t(i,i)")

    j = df.select("k", "v").join(
        (ctx.parallelize([(1, "z")], 2)
         .toDF([("k", "int"), ("z", "str")])), on="k")
    rdd, _, _ = lower(optimize(j.plan, ctx), ctx)
    plan = build_plan(rdd, "collect")
    schemas = {w.write.key_side: w.write.batch_schema
               for w in [s for s in plan if s.write is not None]}
    assert schemas == {"left": ("t(i)", "t(i)"),
                       "right": ("t(i)", "t(s)")}


def test_declared_schema_overflow_falls_back_safely():
    """A sum outgrowing int64 violates the declared "i" column — the pack
    falls back (sniff -> pickle framing) and results stay exact."""
    big = 2**62
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(i % 2, big) for i in range(8)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    out = sorted(df.groupBy("k").agg(sum_(col("v")).alias("t")).collect())
    assert out == [(0, 4 * big), (1, 4 * big)]
    assert_no_leaks(ctx)


def test_grouped_lists_reshuffle_columnar():
    """collect_list output (list-typed values) re-shuffled downstream now
    rides the list codec instead of falling back to pickle framing — and
    the results are identical either way."""
    from repro.core.shuffle import is_columnar, pack_batch, unpack_batch
    # the exact record shape the second shuffle ships: (key, full row
    # containing a list column)
    records = [(i % 2, (i, [j * 7 for j in range(i + 1)], i % 2))
               for i in range(6)]
    bodies = pack_batch(records)
    assert all(is_columnar(b) for b in bodies), \
        "list-valued rows fell back to pickle framing"
    assert [r for b in bodies for r in unpack_batch(b)] == records

    rows = [(i % 6, i % 4) for i in range(600)]
    outs = []
    for columnar in (False, True):
        ctx = FlintContext("flint",
                           FlintConfig(concurrency=4,
                                       shuffle_backend="sqs",
                                       columnar_batches=columnar))
        grouped = (ctx.parallelize(rows, 3)
                   .toDF([("k", "int"), ("v", "int")])
                   .groupBy("k").agg(collect_list(col("v")).alias("vs"))
                   .withColumn("b", col("k") % lit(2)))
        out = (grouped.select("b", col("vs").alias("vs2"))
               .groupBy("b").agg(count_().alias("n")))
        got = sorted(out.collect())
        assert got == [(0, 3), (1, 3)]
        outs.append(got)
        assert_no_leaks(ctx)
    assert outs[0] == outs[1]


# ----------------------------------------------------------- misc API


def test_dataframe_cache_cuts_second_action():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(i % 3, i) for i in range(30)], 2)
          .toDF([("k", "int"), ("v", "int")])
          .groupBy("k").agg(sum_(col("v")).alias("t"))
          .cache())
    first = sorted(df.collect())
    invokes = ctx.ledger.lambda_requests
    second = sorted(df.collect())
    assert first == second
    assert ctx.ledger.lambda_requests - invokes < invokes
    ctx.clear_cache()


def test_cluster_backend_matches_flint():
    rows = [(i % 4, "ab"[i % 2], i) for i in range(40)]
    outs = []
    for backend in ("flint", "cluster"):
        ctx = FlintContext(backend, FlintConfig(concurrency=4))
        df = (ctx.parallelize(rows, 3)
              .toDF([("k", "int"), ("s", "str"), ("v", "int")]))
        q = (df.where(col("v") > lit(3))
             .groupBy("k")
             .agg(sum_(col("v")).alias("t"), max_(col("s")).alias("hi")))
        outs.append(sorted(q.collect()))
    assert outs[0] == outs[1]


def test_count_matches_collect_len():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(i, i) for i in range(25)], 3)
          .toDF([("a", "int"), ("b", "int")]))
    assert df.count() == 25
    assert df.where(col("a") < lit(10)).count() == 10
    assert df.count(optimize=False) == 25


def test_serde_ships_containers_of_functions():
    """The expression compiler closes over LISTS of compiled
    sub-expressions (and itemgetters); serde must walk containers when
    packing closures — a regression here breaks every lowered Project."""
    from repro.core import serde

    fns = [lambda r: r + 1, lambda r: r * 2]

    def apply_all(r):
        return tuple(f(r) for f in fns)

    g = serde.loads_fn(serde.dumps_fn(apply_all))
    assert g(3) == (4, 6)

    def make(fs):
        def run(r):
            return [f(r) for f in fs]
        return run

    h = serde.loads_fn(serde.dumps_fn(make([lambda x: x - 1,
                                            lambda x: (x, x)])))
    assert h(5) == [4, (5, 5)]

    table = {"a": lambda x: x + 10, "b": len}

    def via_dict(r):
        return table["a"](r)

    k = serde.loads_fn(serde.dumps_fn(via_dict))
    assert k(1) == 11


def test_read_csv_end_to_end_with_bool_parsing():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    csv = "a,1,true,1.5\nb,2,false,2.5\na,3,TRUE,3.5\nc,4,0,4.5\n"
    ctx.upload("t.csv", csv.encode())
    df = ctx.read_csv("t.csv", [("s", "str"), ("n", "int"),
                                ("flag", "bool"), ("x", "float")], 2)
    rows = sorted(df.collect())
    assert rows == [("a", 1, True, 1.5), ("a", 3, True, 3.5),
                    ("b", 2, False, 2.5), ("c", 4, False, 4.5)]
    q = (df.where(col("flag"))
         .groupBy("s").agg(sum_(col("n")).alias("t"),
                           max_(col("x")).alias("hi")))
    assert sorted(q.collect()) == [("a", 4, 3.5)]
    assert repr(df) == "DataFrame[s:str, n:int, flag:bool, x:float]"
    assert df.columns == ("s", "n", "flag", "x")
    assert_no_leaks(ctx)


def test_expression_operators_and_errors():
    import pytest
    from repro.sql import Schema, udf
    from repro.sql.expr import AggExpr, Lit, dtype_serde_char

    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(4, 2.0, "ab", True),
                           (9, 3.0, "cd", False)], 2)
          .toDF([("i", "int"), ("f", "float"), ("s", "str"),
                 ("b", "bool")]))
    q = df.select(
        (col("i") - lit(1)).alias("sub"),
        (col("i") / lit(2)).alias("div"),
        (col("i") % lit(3)).alias("mod"),
        (col("i") <= lit(4)).alias("le"),
        (col("i") >= lit(9)).alias("ge"),
        (col("b") | (col("i") != lit(4))).alias("orr"),
        (~col("b")).alias("inv"),
        (col("s") + lit("!")).alias("cat"),
        col("f").cast("str").alias("fs"),
        col("s").substr(1, 1).alias("s1"),
        col("i").cast("bool").alias("ib"),
    )
    assert sorted(q.collect()) == sorted([
        (3, 2.0, 1, True, False, True, False, "ab!", "2.0", "a", True),
        (8, 4.5, 0, False, True, True, True, "cd!", "3.0", "c", True),
    ])
    # dtype checking
    sch = df.schema
    with pytest.raises(TypeError, match="arithmetic"):
        (col("s") - lit(1)).dtype(sch)
    with pytest.raises(TypeError, match="division"):
        (col("s") / lit(1)).dtype(sch)
    with pytest.raises(TypeError, match="boolean"):
        (col("i") & col("b")).dtype(sch)
    with pytest.raises(TypeError, match="boolean"):
        (~col("i")).dtype(sch)
    with pytest.raises(TypeError, match="substr"):
        col("i").substr(1, 2).dtype(sch)
    with pytest.raises(TypeError, match="avg"):
        from repro.sql import avg_
        avg_(col("s")).dtype(sch)
    with pytest.raises(TypeError, match="sum"):
        sum_(col("s")).dtype(sch)
    with pytest.raises(TypeError, match="unsupported literal"):
        Lit(object())
    with pytest.raises(ValueError, match="unknown operator"):
        from repro.sql.expr import BinOp
        BinOp("**", col("i"), lit(2))
    with pytest.raises(ValueError, match="unknown aggregate"):
        AggExpr("median", col("i"))
    with pytest.raises(ValueError, match="argument"):
        AggExpr("sum")
    with pytest.raises(ValueError, match="cannot cast"):
        col("i").cast("complex")
    with pytest.raises(ValueError, match="unknown dtype"):
        Schema([("x", "decimal")])
    with pytest.raises(ValueError, match="duplicate"):
        Schema([("x", "int"), ("x", "int")])
    # schema helpers
    assert len(sch) == 4 and sch == df.schema and hash(sch) == hash(sch)
    assert "i:int" in repr(sch)
    assert dtype_serde_char("list:list:str") == "l(l(s))"
    # udf evaluation + explain tag
    double = udf(lambda x: x * 2, "int", name="double")
    got = sorted(df.select(double(col("i")).alias("d")).collect())
    assert got == [(8,), (18,)]
    assert repr(col("i") + lit(1)) == "<expr (i + 1)>"
    assert repr(sum_(col("i")).alias("t")) == "<agg t:=sum(i)>"


def test_dataframe_take_and_misc_guards():
    import pytest
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(i, i) for i in range(10)], 2)
          .toDF([("a", "int"), ("b", "int")]))
    assert df.take(3) == [(0, 0), (1, 1), (2, 2)]
    with pytest.raises(ValueError, match="n >= 0"):
        df.limit(-1)
    with pytest.raises(ValueError, match="at least one key"):
        df.groupBy()
    with pytest.raises(ValueError, match="at least one key"):
        df.orderBy()
    with pytest.raises(ValueError, match="at least one aggregate"):
        df.groupBy("a").agg()
    with pytest.raises(TypeError, match="bad select argument"):
        df.select(42)
    with pytest.raises(TypeError, match="bad orderBy key"):
        df.orderBy(42)
    # orderBy accepts aliases and expressions; mixed directions
    out = df.orderBy((col("a") % lit(3)).alias("m"), "a",
                     ascending=[True, False]).collect()
    assert out[0] == (9, 9)  # m=0 group, then a desc


def test_declared_schema_never_coerces_mismatched_types():
    """Review regression: struct.pack would silently coerce int->float64
    (and bool->int64) under a declared schema; conformance checking must
    force the fallback so columnar on/off return IDENTICAL values."""
    rows = [(1, 2), (1, 5), (2, 3)]  # ints in a column declared float
    outs = {}
    for columnar in (True, False):
        ctx = FlintContext("flint",
                           FlintConfig(concurrency=4,
                                       columnar_batches=columnar))
        df = ctx.parallelize(rows, 2).toDF([("k", "int"), ("v", "float")])
        outs[columnar] = sorted(
            df.groupBy("k").agg(min_(col("v")).alias("lo")).collect())
    assert outs[True] == outs[False]
    assert all(type(lo) is int for _, lo in outs[True])  # NOT 2.0


def test_repeated_withcolumn_chains_do_not_explode_the_plan():
    """Review regression: Project-merge used to inline a twice-referenced
    non-trivial column at every level -> 2^n expression growth."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = ctx.parallelize([(1,)], 1).toDF([("x0", "int")])
    for i in range(18):
        df = df.withColumn(f"x{i + 1}",
                           col(f"x{i}") + col(f"x{i}"))
    plan = df.explain()
    assert len(plan) < 20_000, f"plan blew up to {len(plan)} chars"
    assert df.select("x18").collect() == [(2 ** 18,)]


def test_cached_frame_shares_one_materialization_across_derived_queries():
    """Review regression: derived queries each cached their OWN lineage;
    now the cache point is a plan barrier and both hits replan from one
    materialization."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    rows = [(i % 4, i) for i in range(40)]
    base_rdd = ctx.parallelize(rows, 2)
    base = (base_rdd.toDF([("k", "int"), ("v", "int")])
            .groupBy("k").agg(sum_(col("v")).alias("t"))
            .cache())
    assert "Cached[]" in base.explain()
    first = sorted(base.where(col("t") > lit(0)).collect())
    tokens_after_first = set(k.split("/")[1]
                             for k in ctx.store.list("_cache/"))
    assert len(tokens_after_first) == 1  # exactly one materialization
    invokes = ctx.ledger.lambda_requests
    second = sorted(base.where(col("t") > lit(10**9)).collect())
    assert second == [] and len(first) == 4
    # the second derived query replanned from the cache: no aggregation
    # shuffle re-ran, and no NEW cache token appeared
    assert set(k.split("/")[1] for k in ctx.store.list("_cache/")) \
        == tokens_after_first
    assert ctx.ledger.lambda_requests - invokes < invokes
    # and the user's RDD object was never mutated by df.cache()
    assert base_rdd.cached is False
    ctx.clear_cache()


def test_merged_filters_short_circuit():
    """Review regression: the optimizer merges sequential wheres into one
    AND; the later guard must not evaluate on rows the earlier filter
    excludes (eager operator.and_ raised ZeroDivisionError here)."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(0, 1), (2, 1), (4, 1)], 2)
          .toDF([("n", "int"), ("one", "int")]))
    q = (df.where(col("n") != lit(0))
           .where(lit(100.0) / col("n").cast("float") > lit(0.0)))
    assert sorted(q.collect()) == [(2, 1), (4, 1)]
    assert sorted(q.collect(optimize=False)) == [(2, 1), (4, 1)]


def test_withcolumn_replacement_preserves_position():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([("a", 1, "x")], 1)
          .toDF([("name", "str"), ("n", "int"), ("tag", "str")]))
    out = df.withColumn("n", col("n") * lit(10))
    assert out.columns == ("name", "n", "tag")
    assert out.collect() == [("a", 10, "x")]


def test_comparison_dtype_mismatch_fails_at_plan_time():
    import pytest
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = ctx.parallelize([(1,)], 1).toDF([("n", "int")])
    with pytest.raises(TypeError, match="cannot compare"):
        df.where(col("n") < lit("5"))


def test_count_on_sorted_limited_plan_skips_the_driver_sort():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(i,) for i in range(30)], 3)
          .toDF([("a", "int")]))
    assert df.orderBy("a").count() == 30
    assert df.orderBy("a", ascending=False).limit(7).count() == 7
    assert df.limit(40).count() == 30


_NT = __import__("collections").namedtuple("_NT", "tag n")


def test_round3_review_regressions():
    """orderBy validates its ascending list; substr rejects 0-based
    starts; serde keeps namedtuple closures intact (exact list/tuple
    only in the container walk)."""
    import pytest
    from repro.core import serde

    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    df = (ctx.parallelize([(1, 2)], 1)
          .toDF([("k", "int"), ("v", "int")]))
    with pytest.raises(ValueError, match="ascending"):
        df.orderBy("k", "v", ascending=[True])
    with pytest.raises(ValueError, match="1-based"):
        col("s").substr(0, 2)
    with pytest.raises(ValueError, match="1-based"):
        col("s").substr(1, -1)

    cfg = _NT("x", 3)

    def use_nt(r):
        return (cfg.tag, cfg.n + r)

    fn = serde.loads_fn(serde.dumps_fn(use_nt))
    assert fn(1) == ("x", 4)
    assert type(fn.__closure__[0].cell_contents) is _NT


_CYCLIC = []
_CYCLIC.append(_CYCLIC)


def test_serde_cyclic_container_global_falls_back_to_pickle():
    """Review regression: the container walk must not recurse forever on
    a cyclic global — cycles take the pickle path like before."""
    from repro.core import serde

    def f():
        return len(_CYCLIC)

    fn = serde.loads_fn(serde.dumps_fn(f))
    assert fn() == 1


# ------------------------------------------------- SQL NULL semantics
# (three-valued logic, docs/dataframe.md): NULLs enter rows via
# outer-join padding; every operator propagates them, where() drops
# NULL-valued predicates, and the vectorized path must agree with the
# row path exactly (falling back to row closures where needed).

_NULL_ROWS = [(1, 10), (2, None), (3, 30), (4, None), (5, 50)]
_NULL_SCHEMA = [("k", "int"), ("v", "int")]


def _null_df(vectorize):
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            vectorize=vectorize))
    return ctx, ctx.parallelize(_NULL_ROWS, 2).toDF(_NULL_SCHEMA)


@pytest.mark.parametrize("vectorize", [True, False],
                         ids=["vectorized", "rows"])
def test_null_predicates_use_three_valued_logic(vectorize):
    ctx, df = _null_df(vectorize)
    # NULL > 15 is NULL, not False: where() drops it, and so does the
    # NEGATED predicate (NOT NULL is NULL)
    assert sorted(df.where(col("v") > lit(15)).collect()) == \
        [(3, 30), (5, 50)]
    assert sorted(df.where(~(col("v") > lit(15))).collect()) == [(1, 10)]
    # OR: NULL | True is True (row k=2 survives via its other leg)
    got = df.where((col("v") > lit(15)) | (col("k") == lit(2))).collect()
    assert sorted(got) == [(2, None), (3, 30), (5, 50)]
    # AND: True & NULL is NULL (dropped), False & NULL is False
    got = df.where((col("k") > lit(0)) & (col("v") > lit(15))).collect()
    assert sorted(got) == [(3, 30), (5, 50)]
    assert sorted(df.where((col("k") < lit(0)) & (col("v") > lit(15)))
                  .collect()) == []
    assert_no_leaks(ctx)


@pytest.mark.parametrize("vectorize", [True, False],
                         ids=["vectorized", "rows"])
def test_null_propagates_through_operators(vectorize):
    ctx, df = _null_df(vectorize)
    got = sorted(df.select("k", (col("v") + lit(1)).alias("v1"),
                           col("v").cast("float").alias("vf")).collect())
    assert got == [(1, 11, 10.0), (2, None, None), (3, 31, 30.0),
                   (4, None, None), (5, 51, 50.0)]
    ctx2 = FlintContext("flint", FlintConfig(concurrency=4,
                                             vectorize=vectorize))
    sdf = ctx2.parallelize([("alpha",), (None,), ("beta",)], 2) \
        .toDF([("s", "str")])
    got = sorted(sdf.select(col("s").substr(1, 2).alias("p")).collect(),
                 key=lambda r: (r[0] is None, r))
    assert got == [("al",), ("be",), (None,)]
    # str equality against NULL is NULL -> dropped (the vectorized str
    # kernel falls back to row closures for this batch)
    assert sorted(sdf.where(col("s") == lit("alpha")).collect()) == \
        [("alpha",)]
    assert_no_leaks(ctx)
    assert_no_leaks(ctx2)


def test_null_semantics_row_vector_parity():
    """The SAME queries through the fused vectorized lowering and the
    row-closure lowering return identical rows — None never silently
    coerces in either path."""
    for q in (lambda df: df.where(col("v") >= lit(10)),
              lambda df: df.select("k", (col("v") * lit(3)).alias("t")),
              lambda df: df.where((col("v") > lit(10)) |
                                  (col("k") > lit(3))),
              lambda df: df.groupBy((col("k") % lit(2)).alias("g")).agg(
                  count_().alias("n"))):
        _, dv = _null_df(True)
        _, dr = _null_df(False)
        assert sorted(q(dv).collect(), key=repr) == \
            sorted(q(dr).collect(), key=repr)


def test_outer_join_padding_flows_through_null_semantics():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    left = ctx.parallelize([(1, "a"), (2, "b"), (3, "c")], 2) \
        .toDF([("k", "int"), ("s", "str")])
    right = ctx.parallelize([(1, 100), (3, 300)], 2) \
        .toDF([("k", "int"), ("w", "int")])
    j = left.join(right, on="k", how="left")
    assert sorted(j.collect()) == [(1, "a", 100), (2, "b", None),
                                   (3, "c", 300)]
    # padded NULL drops out of comparisons and propagates through math
    assert sorted(j.where(col("w") >= lit(0)).collect()) == \
        [(1, "a", 100), (3, "c", 300)]
    got = j.withColumn("w2", col("w") + lit(1)) \
        .where(col("w2") > lit(101)).collect()
    assert sorted(got) == [(3, "c", 300, 301)]
    assert_no_leaks(ctx)
