"""Adaptive query execution (docs/adaptive_execution.md): runtime
shuffle statistics collected from executor responses feed a replanner at
stage boundaries — a shuffle join whose measured build side is small
becomes a broadcast hash join, tiny reduce partitions coalesce (barrier
mode), and cost-model ("auto") transport choices are re-decided from
measured volume. Plus the DataFrame features the same machinery unlocks:
distributed range-partitioned orderBy and left/right/outer joins.

Every strategy change must be invisible in results: each scenario runs
adaptive ON vs OFF and asserts identical answers with zero leaks."""

import operator

import pytest

from repro.core import FaultPlan, FlintConfig, FlintContext
from repro.core import dag as core_dag
from repro.core.dag import estimate_lineage_bytes
from repro.sql import Schema, col, lit
from repro.sql.lower import lower

ADD = operator.add

TRANSIENT_PREFIXES = ("_spill/", "_payload/", "_exchange/", "_result/",
                      "_broadcast/", "_stream/")


def assert_no_leaks(ctx):
    for prefix in TRANSIENT_PREFIXES:
        assert not ctx.store.list(prefix), f"leaked {prefix} keys"
    assert ctx.last_scheduler.sqs._queues == {}, "queues leaked"


def _cfg(**kw):
    # pin adaptive ON by default: this suite asserts adaptive BEHAVIOR,
    # so the CI FLINT_ADAPTIVE=0 leg must not flip it from the env
    kw.setdefault("adaptive", True)
    kw.setdefault("concurrency", 8)
    kw.setdefault("retry_base_s", 0.001)
    kw.setdefault("retry_cap_s", 0.01)
    kw.setdefault("visibility_timeout_s", 0.5)
    kw.setdefault("drain_timeout_s", 1.5)
    return FlintConfig(**kw)


# ------------------------------------------------- broadcast conversion

SMALL = [(k, k * 10) for k in range(50)]
BIG = [(i % 50, "x" * 200 + str(i)) for i in range(20000)]


def _join_rdd(ctx):
    small = ctx.parallelize(SMALL, 2)
    big = ctx.parallelize(BIG, 6)
    return small.join(big, 6)


@pytest.mark.parametrize("pipelined", [True, False],
                         ids=["pipelined", "barrier"])
def test_broadcast_join_converts_and_matches_static(pipelined):
    """An MB-scale probe side against a tiny build side: the measured
    build output beats the shuffle cost, the join converts at runtime,
    and the answer is identical to the static plan with strictly fewer
    shuffled bytes."""
    results, shuffled = {}, {}
    for adaptive in (True, False):
        ctx = FlintContext(config=_cfg(pipeline_stages=pipelined,
                                       adaptive=adaptive))
        results[adaptive] = sorted(_join_rdd(ctx).collect())
        shuffled[adaptive] = (ctx.ledger.bytes_to_sqs
                              + ctx.ledger.bytes_to_s3)
        sched = ctx.last_scheduler
        if adaptive:
            assert sched.adaptive_stats["broadcast_joins"] == 1
        else:
            assert sched.adaptive_stats["broadcast_joins"] == 0
        assert_no_leaks(ctx)
    assert results[True] == results[False]
    assert len(results[True]) == len(BIG)
    assert shuffled[True] < shuffled[False], \
        "broadcast conversion did not reduce shuffled bytes"


def test_broadcast_join_skipped_when_shuffle_cheaper():
    """Tiny data on BOTH sides: the cost model keeps the shuffle (a
    broadcast would pay more PUT/GET requests than the shuffle moves),
    and the answer is still right."""
    ctx = FlintContext(config=_cfg(pipeline_stages=True))
    out = sorted(ctx.parallelize([(k, k) for k in range(20)], 2)
                 .join(ctx.parallelize([(k, -k) for k in range(20)], 2), 2)
                 .collect())
    assert out == [(k, (k, -k)) for k in range(20)]
    assert ctx.last_scheduler.adaptive_stats["broadcast_joins"] == 0
    assert_no_leaks(ctx)


@pytest.mark.parametrize("pipelined", [True, False],
                         ids=["pipelined", "barrier"])
def test_lost_broadcast_object_rebuilds_from_lineage(pipelined):
    """Chaos: an acknowledged ``_broadcast/`` object silently vanishes.
    The probe task's manifest check raises LostBroadcastInput and the
    scheduler replays the small side's lineage, re-publishing identical
    bytes — one charged rebuild, correct results, nothing leaked."""
    plan = FaultPlan(lose_keys=("_broadcast/",))
    ctx = FlintContext(config=_cfg(pipeline_stages=pipelined),
                       fault_plan=plan)
    n = _join_rdd(ctx).count()
    sched = ctx.last_scheduler
    assert n == len(BIG)
    assert sched.adaptive_stats["broadcast_joins"] == 1
    assert sched.adaptive_stats["broadcast_rebuilds"] == 1
    assert sched.recovery_stats["stage_resubmits"] >= 1
    assert sched.faults.stats["lost_objects"] == 1
    assert_no_leaks(ctx)


# ------------------------------------------------ partition coalescing


def test_barrier_coalesces_tiny_reduce_partitions():
    """Tiny data spread over 8 reduce partitions: with every input
    measured at the barrier, contiguous under-floor partitions fold into
    fewer tasks — same answer, fewer invocations."""
    data = [(i % 5, i) for i in range(60)]
    expect = {}
    for k, v in data:
        expect[k] = expect.get(k, 0) + v
    for adaptive in (True, False):
        ctx = FlintContext(config=_cfg(pipeline_stages=False,
                                       adaptive=adaptive))
        out = sorted(ctx.parallelize(data, 4)
                     .reduceByKey(ADD, 8).collect())
        assert out == sorted(expect.items())
        sched = ctx.last_scheduler
        reduce_tasks = sched.stage_stats[-1]["tasks"]
        if adaptive:
            assert sched.adaptive_stats["coalesced_stages"] == 1
            assert reduce_tasks < 8
        else:
            assert sched.adaptive_stats["coalesced_stages"] == 0
            assert reduce_tasks == 8
        assert_no_leaks(ctx)


# --------------------------------------------- transport re-choice


def test_transport_rechosen_from_measured_volume():
    """A selective filter the planner prices at 50% selectivity: the
    first shuffle's cost-model choice (S3, from the inflated estimate)
    is sunk, but the SECOND shuffle re-prices from measured volume and
    moves to SQS. Static keeps both on S3."""
    rows = [(i, "z" * 10000) for i in range(10000)]
    for adaptive in (True, False):
        ctx = FlintContext(config=_cfg(pipeline_stages=False,
                                       shuffle_backend="auto",
                                       adaptive=adaptive,
                                       coalesce_min_bytes=0))
        n = (ctx.parallelize(rows, 4)
             .filter(lambda kv: kv[0] % 1999 == 0)
             .repartition(4)
             .map(lambda kv: kv)
             .repartition(4)
             .count())
        assert n == 6
        sched = ctx.last_scheduler
        if adaptive:
            assert sched.adaptive_stats["transport_rechoices"] >= 1
            assert ctx.ledger.bytes_to_sqs > 0
        else:
            assert sched.adaptive_stats["transport_rechoices"] == 0
            assert ctx.ledger.bytes_to_sqs == 0
        assert_no_leaks(ctx)


def test_explicit_transport_hint_stays_pinned():
    """A per-shuffle hint is a user decision, not a cost-model estimate:
    adaptive never moves it, however wrong the estimate was."""
    rows = [(i, "z" * 10000) for i in range(10000)]
    ctx = FlintContext(config=_cfg(pipeline_stages=False,
                                   shuffle_backend="auto",
                                   coalesce_min_bytes=0))
    n = (ctx.parallelize(rows, 4)
         .filter(lambda kv: kv[0] % 1999 == 0)
         .repartition(4, transport="s3")
         .map(lambda kv: kv)
         .repartition(4, transport="s3")
         .count())
    assert n == 6
    assert ctx.last_scheduler.adaptive_stats["transport_rechoices"] == 0
    assert_no_leaks(ctx)


# ------------------------------------------- distributed orderBy (sort)

SORT_SCHEMA = Schema([("a", "int"), ("b", "int")])


def _skewed_rows():
    # 70% of keys collapse onto one value (splitter duplication), plus a
    # spread tail and negative keys
    rows = [(5, i) for i in range(140)]
    rows += [(i * 13 % 40 - 10, 1000 + i) for i in range(60)]
    return rows


@pytest.mark.parametrize("ascending", [True, False], ids=["asc", "desc"])
def test_orderby_runs_as_distributed_range_sort(ascending):
    rows = _skewed_rows()
    ctx = FlintContext(config=_cfg())
    df = ctx.parallelize(rows, 6).toDF(SORT_SCHEMA)
    q = df.orderBy("a", ascending=ascending)
    # the lowering leaves NOTHING for the driver: no merge limit, no
    # driver ops — the index-ordered merge is already the total order
    rdd, merge_limit, driver_ops = lower(q._planned(True), ctx)
    assert merge_limit is None and driver_ops == []
    got = q.collect()
    assert sorted(got) == sorted(rows)
    keys = [r[0] for r in got]
    assert keys == sorted(keys, reverse=not ascending)
    sched = ctx.last_scheduler
    assert sched.stage_stats[-1]["tasks"] > 1, \
        "sort did not run distributed"
    assert_no_leaks(ctx)


def test_orderby_empty_and_single_row_partitions():
    """Fewer rows than partitions: empty partitions contribute no
    samples and no rows; the range sort still totals correctly."""
    rows = [(9, 0), (-3, 1), (9, 2), (0, 3)]
    ctx = FlintContext(config=_cfg())
    got = (ctx.parallelize(rows, 8).toDF(SORT_SCHEMA)
           .orderBy("a").collect())
    assert [r[0] for r in got] == [-3, 0, 9, 9]
    assert sorted(got) == sorted(rows)
    assert_no_leaks(ctx)


def test_orderby_multi_key_mixed_directions():
    rows = [(i % 3, i * 7 % 11) for i in range(66)]
    ctx = FlintContext(config=_cfg())
    got = (ctx.parallelize(rows, 5).toDF(SORT_SCHEMA)
           .orderBy("a", "b", ascending=[True, False]).collect())
    assert got == sorted(rows, key=lambda r: (r[0], -r[1]))
    assert_no_leaks(ctx)


def test_orderby_matches_driver_sort_fallback():
    """adaptive=False falls back to the driver-side sort of collected
    rows; both paths produce the same key order."""
    rows = _skewed_rows()
    outs = {}
    for adaptive in (True, False):
        ctx = FlintContext(config=_cfg(adaptive=adaptive))
        outs[adaptive] = (ctx.parallelize(rows, 6).toDF(SORT_SCHEMA)
                          .orderBy("a").collect())
        assert_no_leaks(ctx)
    assert [r[0] for r in outs[True]] == [r[0] for r in outs[False]]
    assert sorted(outs[True]) == sorted(outs[False])


def test_orderby_composes_with_downstream_operators():
    """orderBy is no longer FINAL: under adaptive a mid-tree Sort lowers
    as the same distributed range sort, so transforms may follow."""
    rows = _skewed_rows()
    ctx = FlintContext(config=_cfg())
    df = ctx.parallelize(rows, 6).toDF(SORT_SCHEMA)
    got = (df.orderBy("a").where(col("a") >= lit(0))
           .select("a").collect())
    expect = sorted(r[0] for r in rows if r[0] >= 0)
    assert [r[0] for r in got] == expect
    # without adaptive there is no distributed sort to lower mid-tree
    ctx_off = FlintContext(config=_cfg(adaptive=False))
    df_off = ctx_off.parallelize(rows, 6).toDF(SORT_SCHEMA)
    with pytest.raises(ValueError, match="adaptive"):
        df_off.orderBy("a").where(col("a") >= lit(0)).collect()


# ------------------------------------------------- outer join execution

L_SCHEMA = Schema([("k", "int"), ("tag", "str")])
R_SCHEMA = Schema([("k", "int"), ("val", "int")])
L_ROWS = [(i % 7, f"l{i}") for i in range(40)]
R_ROWS = [(k, k * 10) for k in range(5, 10)]


def _ref_join(how):
    lkeys = {r[0] for r in L_ROWS}
    rkeys = {r[0] for r in R_ROWS}
    out = [(k, tag, val) for k, tag in L_ROWS
           for k2, val in R_ROWS if k == k2]
    if how in ("left", "outer"):
        out += [(k, tag, None) for k, tag in L_ROWS if k not in rkeys]
    if how in ("right", "outer"):
        out += [(k, None, val) for k, val in R_ROWS if k not in lkeys]
    return out


def _canon(rows):
    return sorted(rows, key=lambda r: tuple((v is None, v) for v in r))


@pytest.mark.parametrize("how", ["inner", "left", "right", "outer"])
@pytest.mark.parametrize("adaptive", [True, False],
                         ids=["adaptive", "static"])
def test_dataframe_join_how(how, adaptive):
    ctx = FlintContext(config=_cfg(adaptive=adaptive))
    dl = ctx.parallelize(L_ROWS, 4).toDF(L_SCHEMA)
    dr = ctx.parallelize(R_ROWS, 2).toDF(R_SCHEMA)
    got = dl.join(dr, "k", how=how).collect()
    assert _canon(got) == _canon(_ref_join(how))
    assert_no_leaks(ctx)


def test_unsupported_join_how_rejected_at_plan_time():
    ctx = FlintContext(config=_cfg())
    dl = ctx.parallelize(L_ROWS, 2).toDF(L_SCHEMA)
    dr = ctx.parallelize(R_ROWS, 2).toDF(R_SCHEMA)
    with pytest.raises(ValueError, match="inner/left/right/outer"):
        dl.join(dr, "k", how="semi")
    with pytest.raises(ValueError, match="unsupported join how"):
        ctx.parallelize([(1, 2)], 2).join(
            ctx.parallelize([(1, 3)], 2), 2, how="cross")


def test_outer_join_filter_not_pushed_below_join():
    """Filter pushdown would resurrect filtered rows as None-padded
    output on the preserved side — the optimizer must keep the filter
    above any non-inner join."""
    ctx = FlintContext(config=_cfg())
    dl = ctx.parallelize(L_ROWS, 4).toDF(L_SCHEMA)
    dr = ctx.parallelize(R_ROWS, 2).toDF(R_SCHEMA)
    # key-only predicate: for an INNER join it would push to both sides;
    # under how=left pushing it to the right side changes which rows pad
    q = dl.join(dr, "k", how="left").where(col("k") >= lit(3))
    plan = q.explain()
    assert plan.index("Filter") < plan.index("Join"), \
        "filter was pushed below an outer join"
    got = q.collect()
    expect = [r for r in _ref_join("left") if r[0] >= 3]
    assert _canon(got) == _canon(expect)


def test_broadcast_converted_left_join_matches_static():
    """how=left forces the preserved side to stay the probe: adaptive
    may only broadcast the RIGHT side, and the padded output matches the
    static shuffle join exactly."""
    big = [(i % 80, "x" * 200 + str(i)) for i in range(20000)]
    small = [(k, k) for k in range(50)]  # keys 50..79 go unmatched
    results = {}
    for adaptive in (True, False):
        ctx = FlintContext(config=_cfg(adaptive=adaptive))
        left = ctx.parallelize(big, 6)
        right = ctx.parallelize(small, 2)
        out = left.join(right, 6, how="left").collect()
        results[adaptive] = sorted(
            out, key=lambda kv: (kv[0], kv[1][0],
                                 kv[1][1] is None, kv[1][1]))
        if adaptive:
            assert (ctx.last_scheduler
                    .adaptive_stats["broadcast_joins"] == 1)
        assert_no_leaks(ctx)
    assert results[True] == results[False]
    assert any(rv is None for _, (_, rv) in results[True])


# ------------------------------------- estimator staleness regressions


def test_est_memo_ignores_reused_node_ids():
    """The estimate memo keys by id() but stores (node, value) pairs: an
    entry whose node is not the SAME object (id reuse after GC) must be
    recomputed, not served stale."""
    ctx = FlintContext(config=_cfg())
    a = ctx.parallelize([(1, "x" * 100)] * 50, 2)
    b = ctx.parallelize([(2, "y")] * 5, 2)
    planner = core_dag._Planner(1, True, None)
    real = planner._est_bytes(b)
    # poison: another node's entry lands under b's id (simulated reuse)
    planner._est_memo[id(b)] = (a, 10 ** 9)
    assert planner._est_bytes(b) == real


def test_uncached_token_estimate_falls_through_to_lineage():
    """A cache entry can linger in the index after its ``_cache/``
    prefix was swept; the estimator must fall through to the lineage
    walk instead of pricing the dataset at zero bytes."""
    ctx = FlintContext(config=_cfg())
    r = ctx.parallelize([(i % 3, "x" * 200) for i in range(300)], 2)
    cached = r.map(lambda kv: kv).cache()
    cached.collect()  # materialize
    est_ready = estimate_lineage_bytes(cached, ctx._cache_index)
    assert est_ready > 0
    ctx.store.delete_prefix("_cache/")  # sweep behind the index's back
    est_stale = estimate_lineage_bytes(cached, ctx._cache_index)
    assert est_stale > 0, "swept cache prefix estimated as zero bytes"
