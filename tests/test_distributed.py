"""Distribution semantics on forced host devices (subprocess-isolated so
the main pytest process keeps its single CPU device).

These are the scaled-down versions of the production dry-run: a (2, 2)
data x model mesh over 4 host devices, real executions (not just compiles),
checked against single-device results.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 4) -> dict:
    prog = ("import os\n"
            f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(code))
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.launch.mesh import make_host_mesh
        from repro.launch.specs import state_specs, batch_specs
        from repro.runtime.steps import build_train_step, init_train_state
        from repro.runtime.sharding import rules_for, use_rules
        from repro.data.synthetic import lm_batch

        cfg = get_config('yi-9b').reduced(n_layers=2, d_model=64, n_heads=4,
                                          n_kv_heads=2, head_dim=16, d_ff=128,
                                          vocab_size=256,
                                          param_dtype='float32',
                                          compute_dtype='float32')
        tc = TrainConfig(total_steps=3, warmup_steps=1)
        batch = lm_batch(0, 0, 4, 32, cfg.vocab_size)
        step = build_train_step(cfg, tc)

        # single device
        s0 = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        s1, m1 = jax.jit(step)(s0, batch)

        # sharded on a 2x2 mesh
        mesh = make_host_mesh(data=2, model=2)
        with jax.sharding.set_mesh(mesh), use_rules(rules_for(cfg)):
            specs = state_specs(cfg, tc, mesh)
            shardings = jax.tree.map(lambda s: s.sharding, specs)
            s0b = init_train_state(cfg, tc, jax.random.PRNGKey(0))
            s0b = jax.device_put(s0b, shardings)
            s2, m2 = jax.jit(step, donate_argnums=0)(s0b, batch)
        print(json.dumps({
            'loss1': float(m1['loss']), 'loss2': float(m2['loss']),
            'pdiff': float(max(jax.tree.leaves(jax.tree.map(
                lambda a, b: jnp.max(jnp.abs(a - b)).astype(jnp.float32),
                s1.params, jax.device_get(s2.params)))))}))
    """)
    assert res["loss1"] == pytest.approx(res["loss2"], rel=1e-4)
    assert res["pdiff"] < 1e-4


def test_moe_ep_all_to_all_lowering():
    """deepseek-style EP: dispatch/combine must introduce all-to-all or
    equivalent collectives on the model axis and execute correctly."""
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.models.moe import moe_apply, moe_schema
        from repro.common import param as pm
        from repro.runtime.sharding import (param_shardings, rules_for,
                                            use_rules)

        cfg = get_config('deepseek-v2-236b').reduced(
            n_experts=4, top_k=2, capacity_factor=8.0,
            param_dtype='float32', compute_dtype='float32')
        key = jax.random.PRNGKey(0)
        schema = moe_schema(cfg)
        params = pm.init_params(schema, key, jnp.float32)
        x = jax.random.normal(key, (4, 8, cfg.d_model))
        y_ref, aux_ref, _ = moe_apply(params, x, cfg)

        mesh = make_host_mesh(data=2, model=2)
        with jax.sharding.set_mesh(mesh), use_rules(rules_for(cfg)):
            shard = param_shardings(cfg, schema, mesh)
            pp = jax.device_put(params, shard)
            fn = jax.jit(lambda p, x: moe_apply(p, x, cfg)[0])
            hlo = fn.lower(pp, x).compile().as_text()
            y = fn(pp, x)
        colls = sum(hlo.count(c) for c in
                    ('all-to-all', 'all-gather', 'all-reduce',
                     'collective-permute', 'reduce-scatter'))
        print(json.dumps({'err': float(jnp.max(jnp.abs(y - y_ref))),
                          'collectives': colls}))
    """)
    assert res["err"] < 1e-4
    assert res["collectives"] > 0


def test_elastic_checkpoint_reshard():
    """Save under one mesh, restore under a different device count."""
    res = run_with_devices("""
        import json, tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import make_host_mesh
        from repro.launch.specs import state_specs
        from repro.runtime.steps import (abstract_train_state,
                                         init_train_state)
        from repro.runtime.sharding import rules_for, use_rules

        cfg = get_config('yi-9b').reduced(param_dtype='float32',
                                          compute_dtype='float32')
        tc = TrainConfig()
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, state)
            mesh = make_host_mesh(data=4, model=1)  # 'elastic' target
            with jax.sharding.set_mesh(mesh), use_rules(rules_for(cfg)):
                ab = abstract_train_state(cfg, tc)
                specs = state_specs(cfg, tc, mesh)
                shardings = jax.tree.map(lambda s: s.sharding, specs)
                restored = restore_checkpoint(d, 7, ab, shardings)
            diffs = jax.tree.map(
                lambda a, b: float(np.abs(np.asarray(a, np.float64)
                                          - np.asarray(b, np.float64)).max()),
                state.params, restored.params)
            print(json.dumps({'max': max(jax.tree.leaves(diffs))}))
    """)
    assert res["max"] == 0.0


def test_production_mesh_shapes():
    res = run_with_devices("""
        import json, jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print(json.dumps({'single': dict(m1.shape), 'multi': dict(m2.shape)}))
    """, n_devices=512)
    assert res["single"] == {"data": 16, "model": 16}
    assert res["multi"] == {"pod": 2, "data": 16, "model": 16}
