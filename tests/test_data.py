"""Data pipeline: determinism contract + the Flint-backed shard shuffle."""

import numpy as np

from repro.core import FlintConfig, FlintContext
from repro.data.pipeline import byte_tokenizer, shard_token_stream, \
    shuffle_shards
from repro.data.synthetic import lm_batch, taxi_csv, GOLDMAN


def test_lm_batch_deterministic():
    a = lm_batch(7, 42, 4, 32, 1000)
    b = lm_batch(7, 42, 4, 32, 1000)
    c = lm_batch(7, 43, 4, 32, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].dtype == np.int32
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 1000


def test_taxi_csv_schema():
    data = taxi_csv(500, seed=1).decode().strip().splitlines()
    assert len(data) == 500
    row = data[0].split(",")
    assert len(row) == 10
    lon, lat = float(row[2]), float(row[3])
    assert -74.2 < lon < -73.6 and 40.5 < lat < 41.0
    # planted Goldman drop-offs exist (Q1 has an answer)
    hits = 0
    for line in data:
        r = line.split(",")
        if (GOLDMAN[0] <= float(r[2]) <= GOLDMAN[2]
                and GOLDMAN[1] <= float(r[3]) <= GOLDMAN[3]):
            hits += 1
    assert hits >= 1


def test_flint_shard_shuffle_roundtrip():
    """Corpus -> queue shuffle -> shards: no line lost, none duplicated."""
    corpus = "\n".join(f"line-{i:04d}" for i in range(200)).encode()
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    ctx.upload("corpus.txt", corpus)
    keys = shuffle_shards(ctx, "corpus.txt", n_shards=4, read_partitions=3)
    lines = []
    for k in keys:
        lines.extend(ctx.store.get(k).decode().splitlines())
    assert sorted(lines) == sorted(f"line-{i:04d}" for i in range(200))

    batches = list(shard_token_stream(ctx, keys, byte_tokenizer,
                                      seq=16, batch=2))
    assert batches and batches[0]["tokens"].shape == (2, 16)
