"""Flint core engine behaviour — the paper's §III/§VI claims as tests."""

import operator

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FlintConfig, FlintContext
from repro.core.costs import CostLedger, cluster_cost, sqs_request_units
from repro.core.queues import Message, ObjectStoreSim, SQSSim, pack_records, \
    unpack_records
from repro.core import serde

TEXT = "\n".join(["the quick brown fox", "jumps over the lazy dog",
                  "the dog barks"] * 100).encode()


def wordcount(ctx, nparts=4, red_parts=3):
    ctx.upload("text.txt", TEXT)
    return dict(ctx.textFile("text.txt", nparts)
                .flatMap(lambda line: line.split())
                .map(lambda w: (w, 1))
                .reduceByKey(operator.add, red_parts)
                .collect())


EXPECTED = {"the": 300, "quick": 100, "brown": 100, "fox": 100,
            "jumps": 100, "over": 100, "lazy": 100, "dog": 200, "barks": 100}


@pytest.mark.parametrize("backend", ["flint", "cluster", "pyspark"])
def test_wordcount_backends_agree(backend):
    ctx = FlintContext(backend, FlintConfig(concurrency=8))
    assert wordcount(ctx) == EXPECTED


def test_at_least_once_dedup():
    """SQS may duplicate messages (paper §VI); seq-id dedup must hide it."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8, flush_records=20,
                                            duplicate_prob=0.3))
    assert wordcount(ctx) == EXPECTED


@given(nparts=st.integers(1, 19))
@settings(max_examples=10, deadline=None)
def test_split_alignment_property(nparts):
    """Record counts are invariant to how byte ranges split the file."""
    ctx = FlintContext("cluster", FlintConfig(concurrency=4))
    ctx.upload("t.txt", TEXT)
    assert ctx.textFile("t.txt", nparts).count() == 300


def test_executor_chaining():
    """Tasks longer than the lease chain across warm invocations (C3)."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            max_records_per_invoke=40))
    ctx.upload("text.txt", TEXT)
    assert ctx.textFile("text.txt", 2).count() == 300
    assert ctx.last_scheduler.stage_stats[-1]["chained"] >= 4


def test_chaining_with_shuffle_output():
    """Chained producers flush partial combines; consumers re-merge."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            max_records_per_invoke=35,
                                            flush_records=10))
    assert wordcount(ctx) == EXPECTED
    assert ctx.last_scheduler.stage_stats[0]["chained"] > 0


def test_task_retry_on_failure():
    ctx = FlintContext("flint", FlintConfig(concurrency=4),
                       fault_plan={(0, 0): {"fail_attempts": 2}})
    assert wordcount(ctx) == EXPECTED


def test_task_fails_after_max_retries():
    from repro.core import StageFailure
    ctx = FlintContext("flint", FlintConfig(concurrency=4, max_task_retries=1),
                       fault_plan={(0, 0): {"fail_attempts": 99}})
    ctx.upload("text.txt", TEXT)
    with pytest.raises(StageFailure) as exc:
        ctx.textFile("text.txt", 2).count()
    # structured root cause, not message text (docs/fault_tolerance.md)
    e = exc.value
    assert e.error_type == "InjectedFailure"
    assert e.stage_id == 0 and e.task_index == 0
    assert e.attempts == 2  # first try + max_task_retries=1
    assert e.retryable is False


def test_mid_task_failure_is_idempotent():
    """A task dying after partially flushing shuffle output retries with the
    same seq ids — consumers drop the duplicates."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4, flush_records=10),
                       fault_plan={(0, 1): {"fail_after_records": 50}})
    assert wordcount(ctx) == EXPECTED


def test_straggler_speculation():
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            speculation_factor=2.0,
                                            speculation_min_done=2),
                       fault_plan={(0, 0): {"straggle_s": 0.8}})
    ctx.upload("text.txt", TEXT)
    assert ctx.textFile("text.txt", 8).count() == 300
    assert ctx.last_scheduler.stage_stats[-1]["speculated"] >= 1


def test_memory_cap_elastic_partitions():
    """Paper §III-A: overflow is answered by raising the partition count."""
    lines = "\n".join(f"k{i % 400} x" for i in range(1600)).encode()
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            agg_memory_records=120),
                       elastic_retries=3)
    ctx.upload("d.txt", lines)
    out = dict(ctx.textFile("d.txt", 4).map(lambda l: (l.split()[0], 1))
               .reduceByKey(operator.add, 1).collect())
    assert len(out) == 400 and out["k0"] == 4
    assert ctx.partition_multiplier >= 2


def test_join_and_groupby():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    left = ctx.parallelize([(i % 5, f"L{i}") for i in range(20)], 3)
    right = ctx.parallelize([(i % 5, f"R{i}") for i in range(10)], 2)
    assert len(left.join(right, 4).collect()) == 40
    grouped = dict(ctx.parallelize([(i % 3, i) for i in range(12)], 2)
                   .groupByKey(3).collect())
    assert sorted(grouped[0]) == [0, 3, 6, 9]


def test_save_as_text_file():
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    ctx.upload("text.txt", TEXT)
    keys = (ctx.textFile("text.txt", 2).map(lambda l: l.upper())
            .saveAsTextFile("out"))
    assert len(keys) == 2
    assert ctx.store.get(keys[0], 0, 3) == b"THE"


def test_pay_as_you_go_cost_model():
    """Flint cost is usage-driven; cluster cost accrues with wall time."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8))
    wordcount(ctx)
    rep = ctx.cost_report()
    assert rep["lambda_requests"] >= 7  # >= tasks launched
    # shuffle requests land on whichever transport the planner/config
    # resolved ("auto" picks per shuffle via the cost model)
    shuffle_requests = rep["sqs_requests"] + rep["s3_lists"]
    assert shuffle_requests > 0 and rep["total_usd"] > 0
    assert cluster_cost(60.0) == pytest.approx(60 * 11 * 0.40 / 3600)
    assert sqs_request_units(1) == 1
    assert sqs_request_units(65 * 1024) == 2


def test_sqs_message_limits():
    ledger = CostLedger()
    sqs = SQSSim(ledger)
    sqs.create_queue("q")
    with pytest.raises(ValueError):
        sqs.send_batch("q", [Message(b"x" * (257 * 1024), 0, "s")])
    with pytest.raises(ValueError):
        sqs.send_batch("q", [Message(b"x", i, "s") for i in range(11)])
    bodies = pack_records([("k", i) for i in range(10_000)])
    assert all(len(b) <= 256 * 1024 for b in bodies)
    assert sum(len(unpack_records(b)) for b in bodies) == 10_000


def test_payload_spill_roundtrip():
    """>6MB task payloads ride S3 (paper §III-B)."""
    big = b"x" * (7 * 2**20)  # default arg pushes the payload past 6 MB

    def has_big(line, table=big):
        return len(table) > 0

    ctx = FlintContext("flint", FlintConfig(concurrency=2))
    ctx.upload("text.txt", TEXT)
    assert ctx.textFile("text.txt", 2).filter(has_big).count() == 300
    # spill actually happened — and the job-end GC reclaimed every key
    assert ctx.last_scheduler.gc_report.get("_payload/", 0) > 0
    assert not ctx.store.list("_payload/")


def test_serde_lambdas_closures_modules():
    import math

    offset = 10

    def helper(x):
        return x * 2

    fn = lambda x: helper(x) + offset + int(math.sqrt(16))  # noqa: E731
    rebuilt = serde.loads_fn(serde.dumps_fn(fn))
    assert rebuilt(5) == 10 + 10 + 4


def test_object_store_ranged_reads():
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    store.put("k", b"0123456789")
    assert store.get("k", 2, 5) == b"234"
    assert store.size("k") == 10
    assert ledger.s3_gets == 1
