"""Pipelined map→reduce shuffle execution with the EOS protocol
(docs/eos_shuffle.md): consumers are launched concurrently with their
producers, drain as messages arrive, and terminate on per-producer
end-of-stream control messages instead of a post-hoc count table."""

import operator
import pickle
import zlib

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FlintConfig, FlintContext
from repro.core.dag import ShuffleWrite
from repro.core.executors import _ShuffleWriter
from repro.core.queues import pack_records, unpack_records

TEXT = "\n".join(["the quick brown fox", "jumps over the lazy dog",
                  "the dog barks"] * 100).encode()

EXPECTED = {"the": 300, "quick": 100, "brown": 100, "fox": 100,
            "jumps": 100, "over": 100, "lazy": 100, "dog": 200, "barks": 100}


def wordcount(ctx, nparts=4, red_parts=3):
    ctx.upload("text.txt", TEXT)
    return dict(ctx.textFile("text.txt", nparts)
                .flatMap(lambda line: line.split())
                .map(lambda w: (w, 1))
                .reduceByKey(operator.add, red_parts)
                .collect())


def test_pipelined_is_the_default():
    assert FlintConfig().pipeline_stages is True


def test_barrier_mode_still_works():
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            pipeline_stages=False))
    assert wordcount(ctx) == EXPECTED


def test_eos_under_chaining():
    """A chained producer must not emit EOS until its last link; consumers
    still terminate with the full record set."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            max_records_per_invoke=35,
                                            flush_records=10))
    assert wordcount(ctx) == EXPECTED
    assert ctx.last_scheduler.stage_stats[0]["chained"] > 0


def test_retry_after_partial_eosless_failure():
    """A producer that dies after flushing some messages (but before EOS)
    is retried with the same identity: the retry re-emits the same
    sequence ids (deduped) plus the closing EOS."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4, flush_records=10),
                       fault_plan={(0, 1): {"fail_after_records": 50}})
    assert wordcount(ctx) == EXPECTED


def test_speculation_duplicate_eos_dedup():
    """A speculative duplicate of a straggling producer emits a second,
    identical EOS per partition — consumers dedup by producer id."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            speculation_factor=2.0,
                                            speculation_min_done=2),
                       fault_plan={(0, 0): {"straggle_s": 0.8}})
    assert wordcount(ctx, nparts=8, red_parts=4) == EXPECTED
    assert ctx.last_scheduler.stage_stats[0]["speculated"] >= 1


def test_empty_partitions_terminate():
    """Producers send EOS to EVERY partition (total 0 where they wrote
    nothing), so reducers of empty partitions terminate too."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8))
    data = [("only-key", 1)] * 40
    out = dict(ctx.parallelize(data, 3)
               .reduceByKey(operator.add, 6).collect())
    assert out == {"only-key": 40}


def test_pipelined_at_least_once_dedup():
    """Duplicated deliveries (data AND EOS) are absorbed by seq-id /
    producer-id dedup under the streaming drain."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8, flush_records=20,
                                            duplicate_prob=0.3))
    assert wordcount(ctx) == EXPECTED


def test_pipelined_s3_shuffle_backend():
    """EOS markers work over the object-store transport too."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            shuffle_backend="s3",
                                            flush_records=20))
    assert wordcount(ctx) == EXPECTED


@given(nparts=st.integers(1, 6), red_parts=st.integers(1, 5))
@settings(max_examples=6, deadline=None)
def test_barrier_pipelined_result_equality(nparts, red_parts):
    """Property: both execution modes produce identical results on the
    same query, for any partitioning."""
    barrier = wordcount(
        FlintContext("flint", FlintConfig(concurrency=8,
                                          pipeline_stages=False)),
        nparts, red_parts)
    pipelined = wordcount(
        FlintContext("flint", FlintConfig(concurrency=8,
                                          pipeline_stages=True)),
        nparts, red_parts)
    assert barrier == pipelined == EXPECTED


class _CountedPickles:
    """Record whose pickling is observable — for asserting pack_records
    serializes each record exactly once."""

    dumps = 0

    def __init__(self, payload):
        self.payload = payload

    def __reduce__(self):
        _CountedPickles.dumps += 1
        return (_new_counted, (self.payload,))


def _new_counted(payload):
    obj = _CountedPickles.__new__(_CountedPickles)
    obj.payload = payload
    return obj


def test_pack_records_pickles_each_record_exactly_once():
    _CountedPickles.dumps = 0
    records = [_CountedPickles(("key", i, "x" * 50)) for i in range(500)]
    bodies = pack_records(records)
    assert _CountedPickles.dumps == 500
    out = [r for b in bodies for r in unpack_records(b)]
    assert [r.payload for r in out] == [r.payload for r in records]


def test_pack_records_splits_on_cap():
    records = [("k%d" % i, "v" * 60_000) for i in range(40)]
    bodies = pack_records(records)
    assert len(bodies) > 1
    assert all(len(b) <= 256 * 1024 for b in bodies)
    out = [r for b in bodies for r in unpack_records(b)]
    assert out == records


def test_partitioning_is_stable_and_seed_independent():
    """crc32-of-pickled-key routing: identical across writer instances and
    independent of PYTHONHASHSEED, as re-invoked Lambdas require."""
    w = ShuffleWrite(shuffle_id=999, nparts=7, mode="group")
    a = _ShuffleWriter(w, None, "s0t0", None)
    b = _ShuffleWriter(w, None, "s0t1", None)
    for key in ["alpha", ("month", 3, "cash"), 42, ("nested", ("t", 1))]:
        expect = zlib.crc32(
            pickle.dumps(key, protocol=pickle.HIGHEST_PROTOCOL)) % 7
        assert a._partition_of(key) == b._partition_of(key) == expect


def test_pipelined_join_and_groupby():
    ctx = FlintContext("flint", FlintConfig(concurrency=8))
    left = ctx.parallelize([(i % 5, f"L{i}") for i in range(20)], 3)
    right = ctx.parallelize([(i % 5, f"R{i}") for i in range(10)], 2)
    assert len(left.join(right, 4).collect()) == 40
    grouped = dict(ctx.parallelize([(i % 3, i) for i in range(12)], 2)
                   .groupByKey(3).collect())
    assert sorted(grouped[0]) == [0, 3, 6, 9]


def test_chained_links_report_records_in():
    """metered(): a chained (continuation) invocation reports the records
    it actually ingested — the pre-fix code reported 0 for every link that
    hit the lease instead of exhausting its input."""
    from repro.core.costs import CostLedger
    from repro.core.dag import SourceInput, TaskDef
    from repro.core.executors import LambdaSim, executor_main, serialize_task
    from repro.core.queues import ObjectStoreSim, SQSSim

    cfg = FlintConfig(max_records_per_invoke=10)
    ledger = CostLedger()
    store, sqs = ObjectStoreSim(ledger), SQSSim(ledger)
    store.put("t.txt", TEXT)
    env = LambdaSim(cfg, ledger, store, sqs)
    size = store.size("t.txt")
    task = TaskDef(0, 0, SourceInput("t.txt", 0, size, size), [], None)
    resp = executor_main(serialize_task(task, 0, {}), env)
    assert "continuation" in resp  # lease hit after 10 of 300 records
    assert resp["stats"]["records_in"] == 10


def test_equal_numeric_keys_co_partition():
    """1 == 1.0 == True must fold into one key even though their pickles
    differ — the stable partitioner canonicalizes before hashing."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4))
    out = dict(ctx.parallelize([(1, 10), (1.0, 5), (True, 1),
                                ((2, 3.0), 7), ((2, 3), 2)], 3)
               .reduceByKey(operator.add, 4).collect())
    cluster = dict(FlintContext("cluster", FlintConfig(concurrency=4))
                   .parallelize([(1, 10), (1.0, 5), (True, 1),
                                 ((2, 3.0), 7), ((2, 3), 2)], 3)
                   .reduceByKey(operator.add, 4).collect())
    assert out == cluster == {1: 16, (2, 3): 9}


def test_failed_sqs_consumer_recovers_via_redelivery():
    """A consumer that dies mid-task never acked its receives, so after
    the visibility timeout every message it read redelivers to its retry —
    the job completes instead of aborting (receives used to be
    destructive, making any consumer failure fatal)."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend="sqs",
                                            visibility_timeout_s=0.5,
                                            drain_timeout_s=8.0),
                       fault_plan={(1, 0): {"fail_after_records": 1}},
                       elastic_retries=0)
    assert wordcount(ctx, nparts=2, red_parts=2) == EXPECTED
    assert ctx.last_scheduler.stage_stats[-1]["attempts"] >= 3  # 2 tasks + retry


def test_send_to_deleted_queue_is_dropped():
    """A losing speculative duplicate flushing after its stage completed
    must not resurrect deleted queues (and strand messages in them)."""
    from repro.core.costs import CostLedger
    from repro.core.queues import Message, SQSSim
    sqs = SQSSim(CostLedger())
    sqs.create_queue("q")
    sqs.delete_queue("q")
    sqs.send_batch("q", [Message(b"x", 0, "s0t0")])
    assert sqs.approx_len("q") == 0
    assert "q" not in sqs._queues


def test_pipelined_cost_report_still_pay_as_you_go():
    ctx = FlintContext("flint", FlintConfig(concurrency=8))
    wordcount(ctx)
    rep = ctx.cost_report()
    assert rep["lambda_requests"] >= 7
    # "auto" default: the planner resolves the transport per shuffle
    shuffle_requests = rep["sqs_requests"] + rep["s3_lists"]
    assert shuffle_requests > 0 and rep["total_usd"] > 0
