"""Property-based equivalence for the vectorized compiler
(repro.sql.vectorized vs the bound row closures), engine-level A/B runs
with vectorization on/off, the fusion plumbing (mapBatches in the
lineage, explain markers), and a chaos leg proving fault schedules stay
invisible with the columnar map side live.

The contract under test: wherever the vectorized path PRODUCES values,
they are bit-identical (exact concrete types, -0.0 and NaN included) to
what the row closures produce; wherever it cannot guarantee that, it
raises and the fused operator re-runs the chunk through the row
closures — so the only legal divergence is an exception."""

import math
import os
import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FaultPlan, FlintConfig, FlintContext
from repro.core import rdd as R
from repro.sql import (Schema, avg_, col, collect_list, count_, lit, max_,
                       min_, sum_, udf)
from repro.sql import expr as E
from repro.sql import vectorized as V
from repro.sql.lower import lower

CHAOS_SEED = int(os.environ.get("FLINT_CHAOS_SEED", "0"))

SCHEMA = Schema([("i1", "int"), ("i2", "int"), ("f1", "float"),
                 ("f2", "float"), ("b1", "bool"), ("s1", "str")])
DTYPES = [t for _, t in SCHEMA.fields]

_INT_POOL = [0, 1, -1, 7, -13, 2**31, 2**53 - 1, 2**53 + 1, 2**62,
             -2**62, 2**63 - 1, -2**63]
_FLOAT_POOL = [0.0, -0.0, 1.5, -2.25, 1e300, -1e300, 1e-300,
               float("nan"), float("inf"), float("-inf"), 2.0**53]
_STR_POOL = ["", "a", "credit", "cash", "é世界", "2015-01-02 03:04:00",
             "x" * 40, "\t", "naïve"]


def _rand_row(rng):
    return (rng.choice(_INT_POOL), rng.randint(-100, 100),
            rng.choice(_FLOAT_POOL), rng.uniform(-50, 50),
            rng.random() < 0.5, rng.choice(_STR_POOL))


def _rand_rows(rng):
    n = rng.choice([0, 1, 2, 7, 64])
    return [_rand_row(rng) for _ in range(n)]


def _rand_expr(rng, dtype, depth):
    """Random well-typed expression tree over SCHEMA."""
    leaves = [n for n, t in SCHEMA.fields if t == dtype]
    if depth <= 0 or rng.random() < 0.15:
        if leaves and rng.random() < 0.75:
            return E.Col(rng.choice(leaves))
        pool = {"int": [0, 1, -3, 2**40], "float": [0.0, -1.5, 2.5],
                "bool": [True, False], "str": ["", "credit", "é"]}[dtype]
        return E.Lit(rng.choice(pool))
    d = depth - 1
    r = rng.random()
    if dtype == "int":
        if r < 0.25:
            return E.Cast(_rand_expr(rng, rng.choice(
                ["float", "bool", "int"]), d), "int")
        op = rng.choice(["+", "-", "*", "%"])
        return E.BinOp(op, _rand_expr(rng, "int", d),
                       _rand_expr(rng, "int", d))
    if dtype == "float":
        if r < 0.2:
            return E.Cast(_rand_expr(rng, rng.choice(["int", "bool"]), d),
                          "float")
        if r < 0.4:
            return E.BinOp("/", _rand_expr(rng, rng.choice(["int", "float"]),
                                           d),
                           _rand_expr(rng, rng.choice(["int", "float"]), d))
        op = rng.choice(["+", "-", "*", "%"])
        sides = rng.choice([("float", "float"), ("int", "float"),
                            ("float", "int")])
        return E.BinOp(op, _rand_expr(rng, sides[0], d),
                       _rand_expr(rng, sides[1], d))
    if dtype == "bool":
        if r < 0.15:
            return E.Not(_rand_expr(rng, "bool", d))
        if r < 0.35:
            op = rng.choice(["and", "or"])
            return E.BinOp(op, _rand_expr(rng, "bool", d),
                           _rand_expr(rng, "bool", d))
        if r < 0.5:
            return E.Cast(_rand_expr(rng, rng.choice(["int", "float"]), d),
                          "bool")
        cmp_op = rng.choice(["=", "!=", "<", "<=", ">", ">="])
        kind = rng.random()
        if kind < 0.6:
            sides = rng.choice([("int", "int"), ("float", "float"),
                                ("int", "float"), ("float", "int")])
        elif kind < 0.8:
            sides = ("str", "str")
        else:
            sides = ("bool", "bool")
            cmp_op = rng.choice(["=", "!="])
        return E.BinOp(cmp_op, _rand_expr(rng, sides[0], d),
                       _rand_expr(rng, sides[1], d))
    # str
    if r < 0.3:
        return E.Substr(_rand_expr(rng, "str", d), rng.randint(1, 5),
                        rng.randint(0, 6))
    if r < 0.55:
        return E.BinOp("+", _rand_expr(rng, "str", d),
                       _rand_expr(rng, "str", d))
    return E.Cast(_rand_expr(rng, rng.choice(
        ["int", "float", "bool", "str"]), d), "str")


def _same(a, b):
    """Bit-exact scalar equality: same concrete type; floats compared by
    repr (distinguishes -0.0/0.0 and matches NaN to NaN)."""
    if type(a) is not type(b):
        return False
    if isinstance(a, float):
        return repr(a) == repr(b)
    return a == b


def _assert_vec_matches_rows(expr, rows):
    rowfn = expr.bind(SCHEMA)
    row_exc = row_vals = None
    try:
        row_vals = [rowfn(r) for r in rows]
    except Exception as e:  # noqa: BLE001 — the engine surfaces any error
        row_exc = e
    try:
        vfn = expr.bind_vec(SCHEMA)
    except V.VectorizeUnsupported:
        return  # lowering keeps the row closures: nothing to compare
    ingest = V.rows_ingest(DTYPES)
    try:
        with np.errstate(divide="raise", invalid="raise",
                         over="ignore", under="ignore"):
            cols, n = ingest(rows)
            out = V.to_list(vfn(cols, n), n)
    except Exception:  # noqa: BLE001 — fused op re-runs via row closures
        return
    assert row_exc is None, (f"vectorized produced values where the row "
                             f"path raised {row_exc!r}: {expr.sql()}")
    assert len(out) == len(row_vals)
    for a, b in zip(row_vals, out):
        assert _same(a, b), (expr.sql(), a, b)


@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=120, deadline=None)
def test_random_expression_trees_match_row_path(seed):
    """Random expr trees x random batches (NaN/inf floats, ints past
    2**53/2**62, utf8 and empty strings, empty batches): the vectorized
    compile either matches bind() exactly or raises (-> row fallback)."""
    rng = random.Random(seed)
    expr = _rand_expr(rng, rng.choice(["int", "float", "bool", "str"]),
                      rng.randint(0, 3))
    _assert_vec_matches_rows(expr, _rand_rows(rng))


@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=60, deadline=None)
def test_random_filter_masks_match_row_path(seed):
    """filter_stage over random predicates: surviving rows (order, values,
    types) match the row filter — including all-false and empty masks."""
    rng = random.Random(seed)
    pred = _rand_expr(rng, "bool", rng.randint(0, 3))
    rows = _rand_rows(rng)
    rowfn = pred.bind(SCHEMA)
    try:
        expected = [r for r in rows if rowfn(r)]
    except Exception:  # noqa: BLE001
        expected = None  # row path raises; vectorized must not produce
    try:
        stage = V.filter_stage(pred.bind_vec(SCHEMA))
    except V.VectorizeUnsupported:
        return
    try:
        with np.errstate(divide="raise", invalid="raise",
                         over="ignore", under="ignore"):
            cols, n = V.rows_ingest(DTYPES)(rows)
            out_cols, kept = stage(cols, n)
            got = V.rows_emit(out_cols, kept)
    except Exception:  # noqa: BLE001
        return
    assert expected is not None
    assert len(got) == len(expected)
    for ra, rb in zip(expected, got):
        assert all(_same(a, b) for a, b in zip(ra, rb)), (pred.sql(), ra, rb)


# ------------------------------------------------------ grouped aggregation


def _ref_fold(op, keys, vals):
    import operator as _op
    fold = {"sum": _op.add, "min": min, "max": max}[op]
    acc = {}
    for k, v in zip(keys, vals):
        acc[k] = fold(acc[k], v) if k in acc else v
    return acc  # dict preserves first-occurrence order


@given(seed=st.integers(min_value=0, max_value=2**31))
@settings(max_examples=80, deadline=None)
def test_grouped_fold_matches_row_fold(seed):
    """grouped_records vs the row path's per-key dict fold: key order is
    first-occurrence, every slot value is bit-exact — across int columns
    near the overflow guard, float columns with NaN/-0.0, str min/max,
    and both kernels backends (numpy always; jax when importable)."""
    try:
        import jax  # noqa: F401
        backends = ["numpy", "jax"]
    except Exception:  # pragma: no cover - jax is present in this image
        backends = ["numpy"]
    rng = random.Random(seed)
    backend = rng.choice(backends)
    n = rng.choice([0, 1, 5, 40])
    key_vals = [(rng.randint(0, 4), rng.choice(["a", "b", "é"]))
                for _ in range(n)]
    slot_ops, slot_cols, ref_cols = [], [], []
    for _ in range(rng.randint(1, 3)):
        kind = rng.choice(["int", "bigint", "float", "str"])
        if kind == "str":
            op = rng.choice(["min", "max"])
            vals = [rng.choice(_STR_POOL) for _ in range(n)]
            colv = list(vals)
        else:
            op = rng.choice(["sum", "min", "max"])
            if kind == "int":
                vals = [rng.randint(-1000, 1000) for _ in range(n)]
                colv = np.array(vals, dtype=np.int64)
            elif kind == "bigint":
                vals = [rng.choice([2**61, -2**61, 2**62, 5])
                        for _ in range(n)]
                colv = np.array(vals, dtype=np.int64)
            else:
                vals = [rng.choice(_FLOAT_POOL) for _ in range(n)]
                colv = np.array(vals, dtype=np.float64)
        slot_ops.append(op)
        slot_cols.append(colv)
        ref_cols.append(vals)
    kcols = [np.array([k[0] for k in key_vals], dtype=np.int64),
             [k[1] for k in key_vals]]
    try:
        with np.errstate(divide="raise", invalid="raise",
                         over="ignore", under="ignore"):
            got = V.grouped_records(kcols, slot_cols, slot_ops, n, backend)
    except FloatingPointError:
        return  # inf/-inf collisions etc.: the fused op re-runs row-wise
    refs = [_ref_fold(op, key_vals, vals)
            for op, vals in zip(slot_ops, ref_cols)]
    ref_keys = list(refs[0]) if refs and n else []
    assert [k for k, _ in got] == ref_keys
    for k, partials in got:
        for slot, ref in zip(partials, refs):
            assert _same(slot, ref[k]), (slot_ops, k, slot, ref[k])


# ------------------------------------------------------------- engine A/B


def _mk_ctx(vectorize, **kw):
    kw.setdefault("concurrency", 4)
    return FlintContext(config=FlintConfig(vectorize=vectorize, **kw))


TAXI = Schema([("pickup", "str"), ("payment", "str"), ("tip", "float"),
               ("total", "float"), ("miles", "float")])


def _taxi_csv(n=400):
    return "".join(
        f"2015-01-0{1 + i % 9} 0{i % 10}:1{i % 5}:00,"
        f"{'credit' if i % 3 else 'cash'},{i % 7}.25,{i * 1.5},{i % 11}.0\n"
        for i in range(n))


def _sql_job(ctx):
    ctx.upload("t.csv", _taxi_csv().encode())
    df = ctx.read_csv("t.csv", TAXI, 4)
    q = (df.withColumn("hour", col("pickup").substr(12, 2))
           .withColumn("cents", (col("tip") * lit(100.0)).cast("int"))
           .where(col("payment") == lit("credit"))
           .groupBy("hour")
           .agg(sum_(col("cents")).alias("tips"), count_().alias("n"),
                avg_(col("total")).alias("avg_total"),
                min_(col("miles")).alias("min_miles")))
    r1 = sorted(q.collect())
    a = df.groupBy("payment").agg(count_().alias("n"))
    b = df.groupBy("payment").agg(sum_(col("tip")).alias("s"))
    r2 = sorted(a.join(b, on="payment").collect())
    r3 = sorted(df.groupBy("payment")
                .agg(collect_list(col("miles")).alias("ms"),
                     max_(col("total")).alias("mt")).collect())
    return r1, r2, r3


def _exact_rows(xs, ys):
    assert len(xs) == len(ys)
    for rx, ry in zip(xs, ys):
        assert len(rx) == len(ry)
        for a, b in zip(rx, ry):
            if isinstance(a, list):
                assert type(b) is list and len(a) == len(b)
                assert all(_same(x, y) for x, y in zip(a, b))
            else:
                assert _same(a, b), (rx, ry)


def test_engine_ab_vectorized_matches_row_path():
    """Scan->filter->project->agg, join-of-aggregates, and collect_list
    groupBy: vectorize=True and vectorize=False collect identical rows
    with identical concrete types."""
    _exact_rows_all = zip(_sql_job(_mk_ctx(True)), _sql_job(_mk_ctx(False)))
    for vec, row in _exact_rows_all:
        _exact_rows(vec, row)


def test_engine_ab_small_batches_force_chunk_boundaries():
    """vector_batch_rows=7 puts chunk boundaries (and cross-chunk partial
    merging) in play; results still match the row path exactly."""
    for vec, row in zip(_sql_job(_mk_ctx(True, vector_batch_rows=7)),
                        _sql_job(_mk_ctx(False))):
        _exact_rows(vec, row)


def test_engine_ab_empty_and_all_false_filter():
    for vectorize in (True, False):
        ctx = _mk_ctx(vectorize)
        df = (ctx.parallelize([(i, float(i)) for i in range(20)], 3)
              .toDF([("k", "int"), ("v", "float")]))
        assert (df.where(col("k") > lit(10**6))
                .groupBy("k").agg(sum_(col("v")).alias("s"))
                .collect()) == []
        empty = (ctx.parallelize([], 2)
                 .toDF([("k", "int"), ("v", "float")]))
        assert empty.select("k").collect() == []


def test_engine_ab_utf8_and_ragged_fallback():
    """utf8 keys plus a row that breaks int64 (bigint) mid-partition:
    the chunk falls back and both paths agree."""
    rows = [("é世", 1, 2**70), ("b", 2, 5), ("é世", 3, -7), ("b", 4, 2**70)]
    out = {}
    for vectorize in (True, False):
        ctx = _mk_ctx(vectorize)
        df = (ctx.parallelize(rows, 2)
              .toDF([("s", "str"), ("k", "int"), ("v", "int")]))
        out[vectorize] = sorted(
            df.groupBy("s").agg(sum_(col("v")).alias("t"),
                                count_().alias("n")).collect())
    assert out[True] == out[False]
    _exact_rows(out[True], out[False])


def test_udf_falls_back_per_operator_and_explain_marks_it():
    ctx = _mk_ctx(True)
    df = (ctx.parallelize([(i % 3, float(i)) for i in range(30)], 2)
          .toDF([("k", "int"), ("v", "float")]))
    dbl = udf(lambda x: x * 2.0, "float", name="dbl")
    q = (df.where(col("v") > lit(2.0))
         .select("k", dbl(col("v")).alias("d"))
         .groupBy("k").agg(sum_(col("d")).alias("s")))
    plan = q.explain()
    assert "[row-fallback: udf]" in plan
    assert "[vectorized]" in plan
    row_ctx = _mk_ctx(False)
    df2 = (row_ctx.parallelize([(i % 3, float(i)) for i in range(30)], 2)
           .toDF([("k", "int"), ("v", "float")]))
    q2 = (df2.where(col("v") > lit(2.0))
          .select("k", dbl(col("v")).alias("d"))
          .groupBy("k").agg(sum_(col("d")).alias("s")))
    _exact_rows(sorted(q.collect()), sorted(q2.collect()))


def test_fusion_plants_mapbatches_in_the_lineage():
    """The lowering actually fuses: with vectorize on, the lineage below
    the shuffle is a single mapBatches narrow op (scan -> filter ->
    project -> partial agg); with it off, no mapbatches op exists."""
    def kinds(vectorize):
        ctx = _mk_ctx(vectorize)
        ctx.upload("t.csv", _taxi_csv(50).encode())
        df = ctx.read_csv("t.csv", TAXI, 2)
        q = (df.where(col("payment") == lit("credit"))
             .withColumn("hour", col("pickup").substr(12, 2))
             .groupBy("hour").agg(count_().alias("n")))
        from repro.sql.optimizer import optimize
        rdd, _, _ = lower(optimize(q.plan, ctx), ctx)
        seen = []
        node = rdd
        while node is not None:
            if isinstance(node, R.Narrow):
                seen.append(node.kind)
            node = getattr(node, "parent", None)
        return seen
    assert "mapbatches" in kinds(True)
    assert "mapbatches" not in kinds(False)


# ------------------------------------------------------------- chaos leg


def _chaos_ctx(backend, plan, vectorize=True):
    cfg = FlintConfig(shuffle_backend=backend, concurrency=8,
                      flush_records=50, visibility_timeout_s=0.5,
                      drain_timeout_s=1.5, retry_base_s=0.001,
                      retry_cap_s=0.01, max_stage_retries=5,
                      vectorize=vectorize)
    return FlintContext(config=cfg, fault_plan=plan)


def _chaos_job(ctx):
    """One fused-kv aggregation (scan->filter->partial-agg emitting
    pre-combined partials), one join whose map sides ship KVBatch
    columnar carriers, and one CHAINED multi-shuffle pipeline (two
    aggregations feeding a join — consumers that are themselves
    producers — lost-input recovery expands reopens deepest-first, see
    test_chained_multi_shuffle_recovers_deepest_lost_exchange)."""
    data = [(i % 7, i, float(i % 5)) for i in range(300)]
    df = (ctx.parallelize(data, 4)
          .toDF([("k", "int"), ("v", "int"), ("w", "float")]))
    agg = sorted(df.where(col("v") % lit(3) != lit(1))
                 .groupBy("k").agg(sum_(col("v")).alias("t"),
                                   count_().alias("n"),
                                   min_(col("w")).alias("lo")).collect())
    left = (ctx.parallelize([(i % 7, i) for i in range(100)], 4)
            .toDF([("k", "int"), ("a", "int")]))
    right = (ctx.parallelize([(i % 7, float(i)) for i in range(50)], 4)
             .toDF([("k", "int"), ("b", "float")]))
    joined = sorted(left.join(right, on="k").collect())
    chained = sorted(df.groupBy("k").agg(sum_(col("v")).alias("t"))
                     .join(right.groupBy("k").agg(count_().alias("m")),
                           on="k", numPartitions=3).collect())
    return agg, joined, chained


TRANSIENT_PREFIXES = ("_exchange/", "_spill/", "_payload/", "_result/",
                      "_stream/")


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_chaos_vectorized_sql_is_invisible(backend):
    """Seeded fault schedules against the FUSED columnar pipeline
    (vectorized scan->filter->partial-agg plus KVBatch join map sides):
    every run returns the fault-free row-path answer and leaks nothing —
    re-emitted batches stay byte-identical so (src, seq) dedup holds."""
    expected = _chaos_job(_chaos_ctx(backend, None, vectorize=False))
    assert expected == _chaos_job(_chaos_ctx(backend, None, vectorize=True))
    for i in range(3):
        plan = FaultPlan(seed=CHAOS_SEED * 1000 + i,
                         s3_error_prob=0.03, sqs_error_prob=0.03,
                         sqs_delay_prob=0.10, sqs_delay_s=0.02,
                         invoke_throttle_prob=0.02, lose_object_prob=0.02)
        ctx = _chaos_ctx(backend, plan)
        assert _chaos_job(ctx) == expected, (backend, i)
        leaked = [k for p in TRANSIENT_PREFIXES for k in ctx.store.list(p)]
        assert not leaked, leaked[:5]
        assert ctx.last_scheduler.sqs._queues == {}


def test_chained_multi_shuffle_recovers_deepest_lost_exchange():
    """Regression for the old s3 chained-shuffle flake: the FIRST
    ``_exchange/`` object written — a pre-join aggregation's partials,
    the deepest shuffle input of the pipeline — is acknowledged and then
    lost. The middle stage is a consumer that is itself a producer;
    recovery must reopen the DEEPEST lost input (not just the
    shallowest) to reproduce the fault-free answer with no leaks."""
    def chained(ctx):
        df = (ctx.parallelize([(i % 7, i) for i in range(200)], 4)
              .toDF([("k", "int"), ("v", "int")]))
        right = (ctx.parallelize([(i % 7, float(i)) for i in range(50)], 4)
                 .toDF([("k", "int"), ("b", "float")]))
        return sorted(df.groupBy("k").agg(sum_(col("v")).alias("t"))
                      .join(right.groupBy("k")
                            .agg(count_().alias("m")),
                            on="k", numPartitions=3).collect())
    expected = chained(_chaos_ctx("s3", None))
    plan = FaultPlan(seed=CHAOS_SEED + 4242, lose_keys=("_exchange/",))
    ctx = _chaos_ctx("s3", plan)
    assert chained(ctx) == expected
    leaked = [k for p in TRANSIENT_PREFIXES for k in ctx.store.list(p)]
    assert not leaked, leaked[:5]
    assert ctx.last_scheduler.sqs._queues == {}
