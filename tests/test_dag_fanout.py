"""Multi-consumer shuffle DAGs (docs/dag_fanout.md).

1. PROPERTY-BASED RANDOM-DAG EQUIVALENCE: hypothesis-generated DAGs mixing
   narrow ops with reduceByKey / groupByKey / join / union / repartition
   over SHARED sub-lineages (diamonds, self-joins, unions of two
   derivations), executed across the full matrix — pipelined/barrier x
   SQS/S3 x columnar on/off — and checked against a plain-Python reference
   evaluator. Every case also asserts the PLAN-LEVEL invariant: shared
   lineage plans exactly one producer stage (the stage count equals the
   count of distinct shuffle close-sites + the action stage), and that the
   run leaks nothing.

2. Deterministic plan-shape tests for CSE (self-join collapse, diamond,
   union of derivations, transport hints blocking a merge, cse=False).

3. FAULT INJECTION on fan-out: one consumer group's drain dies mid-shuffle
   and recovers via redelivery (SQS) / re-listing (S3) while the sibling
   group completes untouched; a straggling group member's speculative twin
   loses and aborts via its OWN group's release; zero-leak gc_report after
   every case.

4. RDD.cache(): second-action reuse, billing through the ledger, stale
   sweep by the job GC, clear_cache, and the cluster backend.
"""

import operator
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FlintConfig, FlintContext, build_plan
from repro.core.dag import CacheInput, ShuffleRead

ADD = operator.add

TRANSIENT_PREFIXES = ("_spill/", "_payload/", "_exchange/", "_result/",
                      "_broadcast/", "_stream/")


def assert_no_leaks(ctx):
    for prefix in TRANSIENT_PREFIXES:
        assert not ctx.store.list(prefix), f"leaked {prefix} keys"
    assert ctx.last_scheduler.sqs._queues == {}, "queues leaked"


# ---------------------------------------------------------------- the DAG
# A Spec is a tiny lineage description that can be built BOTH into an RDD
# graph (sharing spec objects => sharing RDD nodes) and evaluated by the
# plain-Python reference below. ``vtype`` tracks the value column's type so
# the generator only applies reduceByKey where + is commutative (ints).


class Spec:
    __slots__ = ("op", "kids", "fn", "nparts", "idx", "vtype")

    def __init__(self, op, kids=(), fn=None, nparts=None, idx=None,
                 vtype="int"):
        self.op = op
        self.kids = list(kids)
        self.fn = fn
        self.nparts = nparts
        self.idx = idx
        self.vtype = vtype


def _weight(v):
    """Deterministic, order-independent int view of any value shape."""
    if isinstance(v, (list, tuple)):
        return sum(_weight(x) for x in v) + len(v)
    return v


def m_norm(kv):
    return (kv[0], _weight(kv[1]) % 97)


def m_shift(kv):
    return ((kv[0] + 1) % 5, kv[1])


def f_even_key(kv):
    return kv[0] % 2 == 0


def fm_echo(kv):
    return [kv, ((kv[0] + 2) % 5, kv[1])]


def _normed(spec):
    if spec.vtype == "int":
        return spec
    return Spec("map", [spec], fn=m_norm, vtype="int")


def gen_case(seed: int):
    """Random datasets + a random DAG over them, with deliberate sharing:
    operands are drawn from the whole pool, so earlier nodes (including
    wide ones) frequently feed several consumers."""
    rng = random.Random(seed)
    datasets = [[(rng.randrange(5), rng.randrange(1, 9))
                 for _ in range(rng.randint(5, 12))]
                for _ in range(rng.randint(1, 2))]
    pool = [Spec("data", idx=i, nparts=rng.randint(1, 3))
            for i in range(len(datasets))]
    for _ in range(rng.randint(2, 5)):
        op = rng.choice(["map", "filter", "flatmap", "rbk", "rbk", "gbk",
                         "join", "union", "repart"])
        a = rng.choice(pool)
        if op == "map":
            fn = rng.choice([m_norm, m_shift])
            spec = Spec("map", [a], fn=fn,
                        vtype="int" if fn is m_norm else a.vtype)
        elif op == "filter":
            spec = Spec("filter", [a], fn=f_even_key, vtype=a.vtype)
        elif op == "flatmap":
            spec = Spec("flatmap", [a], fn=fm_echo, vtype=a.vtype)
        elif op == "rbk":
            spec = Spec("rbk", [_normed(a)], fn=ADD,
                        nparts=rng.randint(1, 3), vtype="int")
        elif op == "gbk":
            spec = Spec("gbk", [a], nparts=rng.randint(1, 3), vtype="list")
        elif op == "repart":
            spec = Spec("repart", [a], nparts=rng.randint(1, 3),
                        vtype=a.vtype)
        elif op == "join":
            b = rng.choice(pool)  # b may BE a: a genuine self-join
            spec = Spec("join", [a, b], nparts=rng.randint(1, 3),
                        vtype="pair")
        else:  # union
            b = rng.choice(pool)
            if a.vtype != b.vtype:
                a, b = _normed(a), _normed(b)
            spec = Spec("union", [a, b], vtype=a.vtype)
        pool.append(spec)
    return datasets, pool[-1]


# ------------------------------------------------- engine + reference eval


def build_rdd(spec, ctx, datasets, memo):
    got = memo.get(id(spec))
    if got is not None:
        return got
    k = [build_rdd(s, ctx, datasets, memo) for s in spec.kids]
    if spec.op == "data":
        r = ctx.parallelize(datasets[spec.idx], spec.nparts)
    elif spec.op == "map":
        r = k[0].map(spec.fn)
    elif spec.op == "filter":
        r = k[0].filter(spec.fn)
    elif spec.op == "flatmap":
        r = k[0].flatMap(spec.fn)
    elif spec.op == "rbk":
        r = k[0].reduceByKey(spec.fn, spec.nparts)
    elif spec.op == "gbk":
        r = k[0].groupByKey(spec.nparts)
    elif spec.op == "repart":
        r = k[0].repartition(spec.nparts)
    elif spec.op == "join":
        r = k[0].join(k[1], spec.nparts)
    else:
        r = k[0].union(k[1])
    memo[id(spec)] = r
    return r


def ref_eval(spec, datasets, memo):
    """Plain-Python reference semantics; shared specs evaluate once."""
    got = memo.get(id(spec))
    if got is not None:
        return got
    k = [ref_eval(s, datasets, memo) for s in spec.kids]
    if spec.op == "data":
        out = list(datasets[spec.idx])
    elif spec.op == "map":
        out = [spec.fn(r) for r in k[0]]
    elif spec.op == "filter":
        out = [r for r in k[0] if spec.fn(r)]
    elif spec.op == "flatmap":
        out = [x for r in k[0] for x in spec.fn(r)]
    elif spec.op == "rbk":
        agg = {}
        for key, v in k[0]:
            agg[key] = spec.fn(agg[key], v) if key in agg else v
        out = list(agg.items())
    elif spec.op == "gbk":
        agg = {}
        for key, v in k[0]:
            agg.setdefault(key, []).append(v)
        out = list(agg.items())
    elif spec.op == "repart":
        out = list(k[0])
    elif spec.op == "join":
        left, right = {}, {}
        for key, v in k[0]:
            left.setdefault(key, []).append(v)
        for key, v in k[1]:
            right.setdefault(key, []).append(v)
        out = [(key, (lv, rv)) for key in left if key in right
               for lv in left[key] for rv in right[key]]
    else:  # union
        out = list(k[0]) + list(k[1])
    memo[id(spec)] = out
    return out


def _norm_value(x):
    """Group value-lists are unordered — canonicalize recursively."""
    if isinstance(x, list):
        return sorted((_norm_value(v) for v in x), key=repr)
    if isinstance(x, tuple):
        return tuple(_norm_value(v) for v in x)
    return x


def canon(results):
    return sorted(repr(_norm_value(r)) for r in results)


# --------------------------------------------- the plan-level expectation


def spec_fp(spec, memo):
    """Mirror of the planner's lineage fingerprint at spec level: data
    nodes by identity (each becomes its own parallelize key), derived
    nodes structurally."""
    got = memo.get(id(spec))
    if got is not None:
        return got
    if spec.op == "data":
        fp = ("data", id(spec))
    else:
        fp = (spec.op, id(spec.fn) if spec.fn else None, spec.nparts,
              tuple(spec_fp(s, memo) for s in spec.kids))
    memo[id(spec)] = fp
    return fp


def expected_stage_count(root) -> int:
    """Number of stages a CSE plan must produce: one per DISTINCT shuffle
    close-site (shared lineages close once; a self-join's two identical
    sides close once) plus the action stage."""
    sites = set()
    fpm: dict = {}
    seen: set = set()

    def walk(s):
        if id(s) in seen:
            return
        seen.add(id(s))
        for kid in s.kids:
            walk(kid)
        if s.op in ("rbk", "gbk", "repart"):
            mode = {"rbk": "agg", "gbk": "group", "repart": "repart"}[s.op]
            sites.add((spec_fp(s.kids[0], fpm), mode, s.nparts,
                       id(s.fn) if s.fn else None))
        elif s.op == "join":
            for side in s.kids:
                sites.add((spec_fp(side, fpm), "join", s.nparts, None))

    walk(root)
    return len(sites) + 1


# ------------------------------------------------------------- the matrix

MATRIX = [(pipelined, backend, columnar)
          for pipelined in (True, False)
          for backend in ("sqs", "s3")
          for columnar in (True, False)]


def run_engine_case(seed, pipelined, backend, columnar):
    datasets, root = gen_case(seed)
    expect = canon(ref_eval(root, datasets, {}))
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=6, shuffle_backend=backend,
                                   pipeline_stages=pipelined,
                                   columnar_batches=columnar))
    rdd = build_rdd(root, ctx, datasets, {})
    plan = build_plan(rdd, "collect")
    assert len(plan) == expected_stage_count(root), \
        "shared lineage did not plan exactly one producer stage"
    got = canon(rdd.collect())
    assert got == expect, f"seed {seed}: engine != reference"
    assert_no_leaks(ctx)


def _make_cell_test(pipelined, backend, columnar):
    """>= 100 generated DAGs per matrix cell, identical to the reference
    evaluator, one producer stage per shared lineage, zero leaks. (One
    generated test per cell: the hypothesis shim's wrapper hides the
    signature pytest.mark.parametrize would need.)"""
    @given(seed=st.integers(min_value=0, max_value=10**9))
    @settings(max_examples=100, deadline=None)
    def test(seed):
        run_engine_case(seed, pipelined, backend, columnar)
    test.__name__ = (f"test_random_dag_equivalence_"
                     f"{'pipelined' if pipelined else 'barrier'}_{backend}_"
                     f"{'columnar' if columnar else 'pickle'}")
    test.__qualname__ = test.__name__
    return test


for _cell in MATRIX:
    _cell_test = _make_cell_test(*_cell)
    globals()[_cell_test.__name__] = _cell_test
del _cell, _cell_test


def run_adaptive_ab_case(seed, backend, columnar):
    """The same generated DAG with adaptive replanning ON and OFF, in
    both scheduler modes, must match the reference (and therefore each
    other) and leak nothing — broadcast conversion, coalescing and
    transport re-choice are pure execution-strategy changes."""
    datasets, root = gen_case(seed)
    expect = canon(ref_eval(root, datasets, {}))
    for adaptive in (True, False):
        for pipelined in (True, False):
            ctx = FlintContext(
                "flint",
                FlintConfig(concurrency=6, shuffle_backend=backend,
                            pipeline_stages=pipelined,
                            columnar_batches=columnar,
                            adaptive=adaptive))
            rdd = build_rdd(root, ctx, datasets, {})
            got = canon(rdd.collect())
            assert got == expect, (f"seed {seed} adaptive={adaptive} "
                                   f"pipelined={pipelined}: "
                                   f"engine != reference")
            assert_no_leaks(ctx)


def _make_adaptive_ab_test(backend, columnar):
    @given(seed=st.integers(min_value=0, max_value=10**9))
    @settings(max_examples=25, deadline=None)
    def test(seed):
        run_adaptive_ab_case(seed, backend, columnar)
    test.__name__ = (f"test_random_dag_adaptive_ab_{backend}_"
                     f"{'columnar' if columnar else 'pickle'}")
    test.__qualname__ = test.__name__
    return test


for _cell in [(b, c) for b in ("sqs", "s3") for c in (True, False)]:
    _cell_test = _make_adaptive_ab_test(*_cell)
    globals()[_cell_test.__name__] = _cell_test
del _cell, _cell_test


# ------------------------------------------------- deterministic plan shape


def _ctx(backend="sqs", **kw):
    return FlintContext("flint", FlintConfig(concurrency=8,
                                             shuffle_backend=backend, **kw))


def test_self_join_plans_one_producer_and_one_drain():
    ctx = _ctx()
    agg = (ctx.parallelize([(i % 3, i) for i in range(12)], 2)
           .reduceByKey(ADD, 3))
    plan = build_plan(agg.join(agg, 3), "collect")
    # without CSE: 2 collection stages + 2 agg-read stages + final = 5
    assert len(plan) == 3
    read = plan[-1].tasks[0].input
    assert isinstance(read, ShuffleRead) and read.self_join
    assert len(read.parts) == 1
    # the shared join shuffle has ONE consumer group (one drain per task)
    assert plan[1].write.consumer_groups == 1


def test_self_join_executes_and_matches_plain_join():
    ctx = _ctx()
    agg = (ctx.parallelize([(i % 3, i) for i in range(12)], 2)
           .reduceByKey(ADD, 3))
    assert sorted(agg.join(agg, 3).collect()) == \
        [(0, (18, 18)), (1, (22, 22)), (2, (26, 26))]
    assert_no_leaks(ctx)


def test_diamond_plans_single_producer_with_two_groups():
    ctx = _ctx()
    agg = (ctx.parallelize([(i % 4, 1) for i in range(20)], 2)
           .reduceByKey(ADD, 2))
    c1 = agg.map(lambda kv: (kv[0] % 2, kv[1])).reduceByKey(ADD, 2)
    c2 = agg.map(lambda kv: (0, kv[1])).reduceByKey(ADD, 2)
    plan = build_plan(c1.union(c2), "collect")
    # producer + two consumer stages + final (without CSE: two producers)
    assert len(plan) == 4
    assert plan[0].write.consumer_groups == 2
    groups = sorted(t.input.groups[0] for s in plan[1:3] for t in s.tasks
                    if isinstance(t.input, ShuffleRead)
                    and t.input.parts[0][0] == plan[0].write.shuffle_id)
    assert set(groups) == {0, 1}


def test_union_of_two_derivations_shares_one_producer():
    ctx = _ctx()
    agg = (ctx.parallelize([(i % 3, 1) for i in range(9)], 2)
           .reduceByKey(ADD, 2))
    u = (agg.map(lambda kv: (kv[0], kv[1] * 10))
         .union(agg.map(lambda kv: (kv[0], kv[1] * 100))))
    plan = build_plan(u, "collect")
    assert len(plan) == 2  # one shared producer + the merged final stage
    assert plan[0].write.consumer_groups == 2
    # the two derivations' tasks drain DIFFERENT groups of the same sid
    per_group: dict = {}
    for t in plan[1].tasks:
        per_group.setdefault(t.input.groups[0], set()).add(t.input.partition)
    assert set(per_group) == {0, 1}
    out = sorted(u.collect())
    assert out == [(0, 30), (0, 300), (1, 30), (1, 300), (2, 30), (2, 300)]
    assert_no_leaks(ctx)


def test_different_transport_hints_do_not_merge():
    ctx = _ctx()
    base = ctx.parallelize([(i % 3, 1) for i in range(9)], 2)
    a = base.reduceByKey(ADD, 2, transport="sqs")
    b = base.reduceByKey(ADD, 2, transport="s3")
    plan = build_plan(a.union(b), "collect")
    writes = [s.write for s in plan if s.write is not None]
    assert len(writes) == 2  # different backends => different shuffles
    assert {w.transport for w in writes} == {"sqs", "s3"}


def test_cse_off_restores_per_consumer_producers():
    ctx = _ctx(plan_cse=False)
    agg = (ctx.parallelize([(i % 3, i) for i in range(12)], 2)
           .reduceByKey(ADD, 3))
    plan = build_plan(agg.join(agg, 3), "collect", cse=False)
    assert len(plan) == 5
    assert all(s.write.consumer_groups == 1
               for s in plan if s.write is not None)
    assert sorted(agg.join(agg, 3).collect()) == \
        [(0, (18, 18)), (1, (22, 22)), (2, (26, 26))]
    assert_no_leaks(ctx)


def test_structurally_identical_lineages_merge_without_object_sharing():
    """CSE is content-addressed: two separately-CONSTRUCTED but identical
    derivations (same function objects, same partition counts) share one
    producer stage, even though no RDD object is reused."""
    ctx = _ctx()
    base = ctx.parallelize([(i % 3, 1) for i in range(9)], 2)
    a = base.map(m_norm).reduceByKey(ADD, 2)
    b = base.map(m_norm).reduceByKey(ADD, 2)  # a fresh, identical lineage
    plan = build_plan(a.join(b, 2), "collect")
    # base+map+rbk closes once; the join's two sides fingerprint equal ->
    # self-join collapse: producer, agg stage, final
    assert len(plan) == 3
    res = sorted(a.join(b, 2).collect())
    assert res == [(0, (3, 3)), (1, (3, 3)), (2, (3, 3))]
    assert_no_leaks(ctx)


# --------------------------------------------------------- fault injection


DIAMOND_DATA = [(i % 8, 1) for i in range(24)]


def diamond(ctx):
    agg = ctx.parallelize(DIAMOND_DATA, 3).reduceByKey(ADD, 4)
    c1 = agg.map(lambda kv: (kv[0] % 2, kv[1])).reduceByKey(ADD, 2)
    c2 = agg.map(lambda kv: (0, kv[1] * 10)).reduceByKey(ADD, 2)
    return c1.union(c2)


DIAMOND_EXPECT = [(0, 12), (0, 240), (1, 12)]


def _shuffle_partition_of(key, nparts):
    """Mirror of the engine's stable partitioner, to aim faults at a task
    that is guaranteed to fold records before dying."""
    import pickle
    import zlib
    return zlib.crc32(pickle.dumps(key, protocol=pickle.HIGHEST_PROTOCOL)) \
        % nparts


#: an agg partition holding >= 2 of the 8 diamond keys (stage-1 task index)
FAT_AGG_PARTITION = next(
    p for p in range(4)
    if sum(_shuffle_partition_of(k, 4) == p for k in range(8)) >= 2)


@pytest.mark.parametrize("backend", ["sqs", "s3"])
@pytest.mark.parametrize("pipelined", [True, False])
def test_one_groups_consumer_dies_sibling_group_unaffected(backend,
                                                           pipelined):
    """A consumer task of group 0 dies mid-drain (after folding records);
    its retry recovers via redelivery (SQS) / re-listing (S3). The sibling
    group's stage — draining the SAME shared shuffle — completes
    untouched, and results match the fault-free run."""
    cfg = dict(concurrency=8, shuffle_backend=backend,
               pipeline_stages=pipelined, visibility_timeout_s=0.5,
               drain_timeout_s=8.0)
    clean_ctx = FlintContext("flint", FlintConfig(**cfg))
    clean = sorted(diamond(clean_ctx).collect())
    assert clean == DIAMOND_EXPECT
    # stage 1 is the first consumer stage of the shared agg shuffle
    faulty = FlintContext(
        "flint", FlintConfig(**cfg),
        fault_plan={(1, FAT_AGG_PARTITION): {"fail_after_records": 1}},
        elastic_retries=0)
    assert sorted(diamond(faulty).collect()) == clean
    stats = {s["stage"]: s for s in faulty.last_scheduler.stage_stats}
    assert stats[1]["attempts"] > stats[1]["tasks"]  # the retry happened
    assert stats[2]["attempts"] == stats[2]["tasks"]  # sibling untouched
    assert_no_leaks(faulty)
    assert faulty.last_scheduler.gc_report is not None


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_straggling_group_member_speculation_loser_aborts_per_group(
        backend):
    """A straggler in ONE consumer group draws a speculative twin; the
    loser aborts via its own group's release (QueueGone / group tombstone)
    while the sibling group and the winner are unaffected."""
    ctx = FlintContext(
        "flint",
        FlintConfig(concurrency=12, shuffle_backend=backend,
                    visibility_timeout_s=0.5, drain_timeout_s=8.0,
                    speculation_factor=2.0, speculation_min_done=2),
        fault_plan={(1, 1): {"straggle_s": 0.6}}, elastic_retries=0)
    assert sorted(diamond(ctx).collect()) == DIAMOND_EXPECT
    stats = {s["stage"]: s for s in ctx.last_scheduler.stage_stats}
    assert stats[1]["speculated"] >= 1
    assert_no_leaks(ctx)


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_fanout_under_duplicate_delivery(backend):
    """5% duplicated deliveries: per-group dedup keeps every group's fold
    exact."""
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=8, shuffle_backend=backend,
                                   duplicate_prob=0.05,
                                   visibility_timeout_s=0.5,
                                   drain_timeout_s=8.0))
    assert sorted(diamond(ctx).collect()) == DIAMOND_EXPECT
    assert_no_leaks(ctx)


# ------------------------------------------------------------- RDD.cache()


def test_cache_reuses_materialization_on_second_action():
    ctx = _ctx()
    agg = (ctx.parallelize([(i % 5, 1) for i in range(50)], 4)
           .reduceByKey(ADD, 2).cache())
    r1 = sorted(agg.collect())
    first_invokes = ctx.ledger.lambda_requests
    assert ctx.store.list("_cache/")  # materialized (billed PUTs)
    r2 = sorted(agg.collect())
    second_invokes = ctx.ledger.lambda_requests - first_invokes
    assert r1 == r2 == [(k, 10) for k in range(5)]
    # cache hit plans ONLY the action stage: 2 tasks vs 4 + 2
    assert second_invokes < first_invokes
    plan = build_plan(agg, "collect", cache_index=ctx._cache_index)
    assert len(plan) == 1
    assert_no_leaks(ctx)


def test_cached_rdd_extends_into_downstream_lineage():
    ctx = _ctx()
    agg = (ctx.parallelize([(i % 5, 1) for i in range(50)], 4)
           .reduceByKey(ADD, 2).cache())
    assert sorted(agg.collect()) == [(k, 10) for k in range(5)]
    out = sorted(agg.map(lambda kv: (kv[0] % 2, kv[1]))
                 .reduceByKey(ADD, 2).collect())
    assert out == [(0, 30), (1, 20)]
    assert_no_leaks(ctx)


def test_cache_survives_job_gc_until_cleared():
    ctx = _ctx()
    agg = (ctx.parallelize([(i, 1) for i in range(10)], 2)
           .reduceByKey(ADD, 2).cache())
    agg.collect()
    # the job GC ran at action end (scheduler shutdown) and kept the cache
    assert ctx.store.list("_cache/")
    n = ctx.clear_cache()
    assert n > 0 and not ctx.store.list("_cache/")
    # after clearing, the lineage simply recomputes
    assert sorted(agg.collect()) == [(i, 1) for i in range(10)]


def test_stale_cache_keys_are_swept_by_job_gc():
    ctx = _ctx()
    ctx.store.put("_cache/deadbeef/2/p0/000000-feedface", b"stale")
    (ctx.parallelize([(1, 1)], 1).reduceByKey(ADD, 1).collect())
    assert not ctx.store.list("_cache/deadbeef/")
    assert ctx.last_scheduler.gc_report.get("_cache/") == 1


@pytest.mark.parametrize("backend", ["sqs", "s3"])
def test_cache_and_cse_compose(backend):
    """A cached diamond: first action materializes the shared producer
    once (CSE), second action replans from the cache."""
    ctx = _ctx(backend)
    agg = (ctx.parallelize(DIAMOND_DATA, 3).reduceByKey(ADD, 4).cache())
    c1 = agg.map(lambda kv: (kv[0] % 2, kv[1])).reduceByKey(ADD, 2)
    first = sorted(c1.collect())
    second_plan = build_plan(c1, "collect", cache_index=ctx._cache_index)
    # cache hit: agg's producer stage is gone; only c1's shuffle remains
    assert len(second_plan) == 2
    assert sorted(c1.collect()) == first == [(0, 12), (1, 12)]
    assert_no_leaks(ctx)


def test_cache_op_disables_chaining_for_deterministic_keys():
    """A task carrying a cache op must not chain: per-link slices would
    pack with lease-dependent boundaries, leaving divergent key sets for
    retries/twins to collide with. The op wins over the chaining hook."""
    ctx = _ctx(max_records_per_invoke=10, flush_records=5)
    ctx.upload("nums.txt", "\n".join(str(i % 7) for i in range(60)).encode())
    src = (ctx.textFile("nums.txt", 2)
           .map(lambda s: (int(s), 1)).cache())
    out = sorted(src.reduceByKey(ADD, 2).collect())
    assert out == [(k, 60 // 7 + (1 if k < 60 % 7 else 0)) for k in range(7)]
    assert ctx.last_scheduler.stage_stats[0]["chained"] == 0
    # and the second action plans from the materialization, not the source
    plan = build_plan(src.reduceByKey(ADD, 2), "collect",
                      cache_index=ctx._cache_index)
    assert isinstance(plan[0].tasks[0].input, CacheInput)
    assert sorted(src.reduceByKey(ADD, 2).collect()) == out


def test_cache_materialization_respects_memory_cap():
    """The cache tee is executor state like any other materialization:
    past agg_memory_records it raises MemoryCapExceeded and the context
    answers with elasticity (more partitions, smaller tees)."""
    data = [(i, 1) for i in range(32)]
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=8, agg_memory_records=20),
                       elastic_retries=2)
    cached = (ctx.parallelize(data, 2).reduceByKey(ADD, 4)
              .flatMap(lambda kv: [kv] * 4).cache())
    out = sorted(cached.collect())
    assert out == sorted([(i, 1) for i in range(32)] * 4)
    assert ctx.partition_multiplier > 1  # elasticity actually fired
    assert_no_leaks(ctx)


def test_source_rooted_cache_shrinks_via_source_resplit():
    """Elasticity reaches source-rooted materializations too: byte-range
    splits re-cut under the partition multiplier, so a cache() directly
    on a textFile lineage recovers from the memory cap instead of
    re-running an identical doomed plan."""
    ctx = FlintContext("flint",
                       FlintConfig(concurrency=8, agg_memory_records=60),
                       elastic_retries=2)
    ctx.upload("lines.txt",
               "\n".join(str(i % 9) for i in range(100)).encode())
    cached = (ctx.textFile("lines.txt", 1)
              .map(lambda s: (int(s), 1)).cache())
    out = sorted(cached.reduceByKey(ADD, 2).collect())
    assert out == [(k, 100 // 9 + (1 if k < 100 % 9 else 0))
                   for k in range(9)]
    assert ctx.partition_multiplier > 1  # the re-split actually happened
    assert_no_leaks(ctx)


def test_failed_materializing_action_unpins_cache_keys():
    """A terminal StageFailure mid-materialization unregisters the
    pending token, so the job GC sweeps the partial _cache/ batches
    instead of treating them as live forever."""
    from repro.core import StageFailure
    ctx = FlintContext("flint", FlintConfig(concurrency=8),
                       fault_plan={(0, 0): {"fail_attempts": 10}},
                       elastic_retries=0)
    cached = (ctx.parallelize([(i % 3, 1) for i in range(12)], 2)
              .map(lambda kv: kv).cache())
    with pytest.raises(StageFailure):
        cached.reduceByKey(ADD, 2).collect()
    assert ctx._cache_index == {}
    assert not ctx.store.list("_cache/"), "partial cache batches leaked"


def test_unserializable_fn_lineage_recomputes_instead_of_caching():
    """A lineage whose fingerprint rests on object identity (an
    unserializable callable) must not be content-addressed: id reuse
    across actions could serve the wrong materialization. Such a cache()
    is a no-op — the lineage recomputes."""
    import threading
    lock = threading.Lock()  # unpicklable closure freight

    def fn(kv, _l=lock):
        return (kv[0], kv[1] * 2)

    ctx = FlintContext("cluster", FlintConfig())  # cluster ships fns raw
    cached = ctx.parallelize([(1, 2), (2, 3)], 1).map(fn).cache()
    assert sorted(cached.collect()) == [(1, 4), (2, 6)]
    assert ctx._cache_index == {} and not ctx.store.list("_cache/")
    assert sorted(cached.collect()) == [(1, 4), (2, 6)]


def test_cache_on_cluster_backend():
    ctx = FlintContext("cluster", FlintConfig())
    agg = (ctx.parallelize([(i % 3, 1) for i in range(12)], 2)
           .reduceByKey(ADD, 2).cache())
    r1 = sorted(agg.collect())
    r2 = sorted(agg.collect())
    assert r1 == r2 == [(0, 4), (1, 4), (2, 4)]
    assert ctx.store.list("_cache/")
    ctx.clear_cache()
    assert not ctx.store.list("_cache/")
