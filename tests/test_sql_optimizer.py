"""Golden plan-shape tests for the DataFrame optimizer.

Each rule gets a deterministic before/after `explain()` comparison:
predicate pushdown through Project / below Join / below Aggregate (and
the cases that must BLOCK it — non-deterministic expressions, predicates
on aggregate outputs), projection pruning into the scan and below
shuffles, limit combining, partial-aggregation selection, and the
cost-model transport choice. Golden strings pin the exact tree;
regressions in rule order or formatting fail loudly.
"""

import textwrap

import pytest

from repro.core import FlintConfig, FlintContext
from repro.sql import (Schema, col, collect_list, count_, lit, max_, min_,
                       sum_, udf)

TAXI = Schema([
    ("pickup", "str"), ("dropoff", "str"), ("dropoff_lon", "float"),
    ("dropoff_lat", "float"), ("trip_miles", "float"),
    ("payment_type", "str"), ("tip", "float"), ("total", "float"),
    ("precip", "float"), ("color", "str"),
])

CSV_ROW = "2015-01-02 03:04:00,2015-01-02 04:04:00,-74.0,40.7,1.5,credit,1.25,7.0,0.0,yellow\n"


def _ctx(**kw):
    # goldens pin the "auto" transport choice; keep them independent of
    # the CI matrix's FLINT_SHUFFLE_BACKEND / FLINT_ADAPTIVE env defaults
    kw.setdefault("shuffle_backend", "auto")
    kw.setdefault("adaptive", True)
    ctx = FlintContext("flint", FlintConfig(concurrency=4, **kw))
    ctx.upload("taxi.csv", (CSV_ROW * 50).encode())
    return ctx


def golden(text: str) -> str:
    return textwrap.dedent(text).strip()


# -------------------------------------------------- pushdown + pruning


def test_filter_pushes_through_project_and_prunes_scan():
    df = _ctx().read_csv("taxi.csv", TAXI, 4)
    q = (df.withColumn("hour", col("pickup").substr(12, 2))
           .withColumn("tip_cents", (col("tip") * lit(100.0)).cast("int"))
           .where(col("payment_type") == lit("credit"))
           .groupBy("hour")
           .agg(sum_(col("tip_cents")).alias("tips"),
                count_().alias("n")))
    assert q.explain() == golden("""
        Aggregate[keys=[hour], aggs=[tips:=sum(tip_cents), n:=count(*)], combine=map_side, transport=sqs] [vectorized]
          Project[hour:=substr(pickup, 12, 2), tip_cents:=cast((tip * 100.0) as int)] [vectorized]
            Filter[(payment_type = 'credit')] [vectorized]
              Scan[taxi.csv, cols=[pickup, payment_type, tip], parts=4] [vectorized]
    """)
    # the raw plan keeps the user's op order and the full scan
    assert q.explain(optimize=False) == golden("""
        Aggregate[keys=[hour], aggs=[tips:=sum(tip_cents), n:=count(*)], combine=none] [vectorized]
          Filter[(payment_type = 'credit')] [vectorized]
            Project[pickup, dropoff, dropoff_lon, dropoff_lat, trip_miles, payment_type, tip, total, precip, color, hour, tip_cents:=cast((tip * 100.0) as int)] [vectorized]
              Project[pickup, dropoff, dropoff_lon, dropoff_lat, trip_miles, payment_type, tip, total, precip, color, hour:=substr(pickup, 12, 2)] [vectorized]
                Scan[taxi.csv, cols=[pickup, dropoff, dropoff_lon, dropoff_lat, trip_miles, payment_type, tip, total, precip, color], parts=4] [vectorized]
    """)


def test_filter_splits_below_join_by_side():
    ctx = _ctx()
    left = (ctx.parallelize([(1, "x", 2)], 2)
            .toDF([("k", "int"), ("ls", "str"), ("lv", "int")]))
    right = (ctx.parallelize([(1, 5)], 2)
             .toDF([("k", "int"), ("rv", "int")]))
    q = (left.join(right, on="k")
         .where((col("lv") > lit(1)) & (col("rv") < lit(9))
                & (col("k") != lit(0))))
    # lv-conjunct -> left, rv-conjunct -> right, key-only conjunct -> BOTH
    # (ls stays: it is part of the join's output)
    assert q.explain() == golden("""
        Join[on=[k], how=inner, transport=sqs] [vectorized]
          Filter[((lv > 1) and (k != 0))] [vectorized]
            RddScan[cols=[k, ls, lv], parts=2]
          Filter[((rv < 9) and (k != 0))] [vectorized]
            RddScan[cols=[k, rv], parts=2]
    """)
    # selecting away ls narrows the left shuffle input below the filter
    q2 = q.select("k", "lv", "rv")
    assert q2.explain() == golden("""
        Project[k, lv, rv] [vectorized]
          Join[on=[k], how=inner, transport=sqs] [vectorized]
            Project[k, lv] [vectorized]
              Filter[((lv > 1) and (k != 0))] [vectorized]
                RddScan[cols=[k, ls, lv], parts=2]
            Filter[((rv < 9) and (k != 0))] [vectorized]
              RddScan[cols=[k, rv], parts=2]
    """)


def test_filter_on_keys_pushes_below_aggregate_but_agg_output_stays():
    ctx = _ctx()
    df = (ctx.parallelize([(1, 2)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    q = (df.groupBy("k").agg(sum_(col("v")).alias("total"))
         .where((col("k") > lit(0)) & (col("total") > lit(10))))
    assert q.explain() == golden("""
        Filter[(total > 10)] [vectorized]
          Aggregate[keys=[k], aggs=[total:=sum(v)], combine=map_side, transport=sqs] [vectorized]
            Filter[(k > 0)] [vectorized]
              RddScan[cols=[k, v], parts=2]
    """)


def test_nondeterministic_predicate_blocks_pushdown():
    ctx = _ctx()
    df = (ctx.parallelize([(1, 2)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    flaky = udf(lambda v: v > 0, "bool", name="flaky",
                deterministic=False)
    # non-deterministic predicate stays ABOVE the project
    q = df.select("k", (col("v") * lit(2)).alias("w")) \
          .where(flaky(col("w")))
    assert q.explain() == golden("""
        Filter[flaky!(w)] [row-fallback: udf]
          Project[k, w:=(v * 2)] [vectorized]
            RddScan[cols=[k, v], parts=2]
    """)
    # ... and a deterministic predicate over a NON-deterministic projected
    # column is blocked too (substitution would re-evaluate the udf)
    rnd = udf(lambda k: k * 3, "int", name="rnd", deterministic=False)
    q2 = df.select("k", rnd(col("k")).alias("r")).where(col("r") > lit(0))
    assert q2.explain() == golden("""
        Filter[(r > 0)] [vectorized]
          Project[k, r:=rnd!(k)] [row-fallback: udf]
            RddScan[cols=[k, v], parts=2]
    """)


def test_pruning_drops_unused_aggregates_and_narrows_join_inputs():
    ctx = _ctx()
    df = (ctx.parallelize([(1, "x", 2, 3)], 2)
          .toDF([("k", "int"), ("s", "str"), ("v", "int"), ("w", "int")]))
    q = (df.groupBy("k")
           .agg(sum_(col("v")).alias("sv"), sum_(col("w")).alias("sw"),
                max_(col("s")).alias("ms"))
           .select("k", "sv"))
    # sw/ms are never used: dropped, and the scan narrows to k,v
    assert q.explain() == golden("""
        Project[k, sv] [vectorized]
          Aggregate[keys=[k], aggs=[sv:=sum(v)], combine=map_side, transport=sqs] [vectorized]
            Project[k, v] [vectorized]
              RddScan[cols=[k, s, v, w], parts=2]
    """)


# ------------------------------------------------- partial-agg selection


def test_collect_list_blocks_map_side_combine():
    ctx = _ctx()
    df = (ctx.parallelize([(1, 2)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    alg = df.groupBy("k").agg(sum_(col("v")).alias("t"),
                              min_(col("v")).alias("lo"),
                              max_(col("v")).alias("hi"),
                              count_().alias("n"))
    assert "combine=map_side" in alg.explain()
    mixed = df.groupBy("k").agg(sum_(col("v")).alias("t"),
                                collect_list(col("v")).alias("vs"))
    assert "combine=none" in mixed.explain()


# ----------------------------------------------------------- limits


def test_adjacent_limits_combine_and_topn_plan_shape():
    ctx = _ctx()
    df = (ctx.parallelize([(i, i) for i in range(20)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    q = df.limit(7).limit(3)
    assert q.explain() == golden("""
        Limit[3]
          RddScan[cols=[k, v], parts=2]
    """)
    topn = df.orderBy("v", ascending=False).limit(2)
    assert topn.explain() == golden("""
        Limit[2]
          Sort[v desc]
            RddScan[cols=[k, v], parts=2]
    """)
    assert topn.collect() == [(19, 19), (18, 18)]


def test_transformations_after_final_operators_raise():
    ctx = _ctx()
    df = (ctx.parallelize([(1, 2)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    with pytest.raises(ValueError, match="final"):
        df.limit(1).select("k")
    # orderBy is NOT final anymore: it composes, and under adaptive the
    # mid-tree Sort lowers as a distributed range-partitioned sort
    assert df.orderBy("k").where(col("k") > lit(0)).collect() == [(1, 2)]


# --------------------------------------------------- transport choice


def test_cost_model_picks_sqs_small_and_s3_large():
    ctx = _ctx()  # "auto" via _ctx
    small = ctx.read_csv("taxi.csv", TAXI, 4)
    q = small.groupBy("color").agg(count_().alias("n"))
    assert "transport=sqs" in q.explain()

    ctx.upload("big.csv", (CSV_ROW * 400_000).encode())  # ~36 MB
    big = ctx.read_csv("big.csv", TAXI, 2)
    q2 = big.groupBy("pickup").agg(sum_(col("total")).alias("t"),
                                   min_(col("dropoff")).alias("d"))
    assert "transport=s3" in q2.explain()


def test_pinned_backend_skips_transport_choice():
    ctx = _ctx(shuffle_backend="s3")
    df = ctx.read_csv("taxi.csv", TAXI, 4)
    q = df.groupBy("color").agg(count_().alias("n"))
    assert "transport=" not in q.explain()  # runtime default applies


# ----------------------------------------------------------- API guards


def test_api_validation_errors():
    ctx = _ctx()
    df = (ctx.parallelize([(1, 2)], 2)
          .toDF([("k", "int"), ("v", "int")]))
    with pytest.raises(KeyError, match="nope"):
        df.select("nope")
    with pytest.raises(ValueError, match="alias"):
        df.select(col("k") + lit(1))
    with pytest.raises(ValueError, match="duplicate"):
        df.groupBy("k").agg(sum_(col("v")), sum_(col("v")))
    with pytest.raises(ValueError, match="inner/left/right/outer"):
        df.join(df, on="k", how="cross")
    other = (ctx.parallelize([(1, 2)], 2)
             .toDF([("k", "int"), ("v", "int")]))
    with pytest.raises(ValueError, match="share non-key"):
        df.join(other, on="k").schema
    with pytest.raises(TypeError, match="not.*boolean|boolean"):
        df.where(col("k") + lit(1)).schema
