import os
import sys

# tests must see the single real CPU device (the dry-run sets its own flags
# in a separate process); keep any user XLA_FLAGS out of the picture.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# Optional-dependency shim: if `hypothesis` is absent, install a tiny
# deterministic stand-in covering the subset this suite uses
# (given/settings + integers/floats/sampled_from/booleans), so every test
# module collects and property tests still run over seeded random samples.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng):
            return self._draw(rng)

    def _integers(min_value=-2**31, max_value=2**31):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=None, max_value=None, allow_nan=True):
        lo = -1e9 if min_value is None else min_value
        hi = 1e9 if max_value is None else max_value
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _none():
        return _Strategy(lambda rng: None)

    def _text(max_size=20, **_kw):
        alphabet = "abc XYZ09_é世"
        return _Strategy(lambda rng: "".join(
            rng.choice(alphabet) for _ in range(rng.randint(0, max_size))))

    def _one_of(*strategies):
        return _Strategy(
            lambda rng: rng.choice(strategies).example_from(rng))

    def _lists(elements, min_size=0, max_size=10, **_kw):
        return _Strategy(lambda rng: [
            elements.example_from(rng)
            for _ in range(rng.randint(min_size, max_size))])

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.example_from(rng)
                                           for s in strategies))

    def _settings(**kwargs):
        def deco(fn):
            fn._shim_settings = dict(kwargs)
            return fn
        return deco

    def _given(*arg_strategies, **strategies):
        def deco(fn):
            max_examples = getattr(fn, "_shim_settings",
                                   {}).get("max_examples", 10)

            def wrapper(*args, **kwargs):
                rng = random.Random(0xF11A7)
                for _ in range(max_examples):
                    pos = tuple(s.example_from(rng) for s in arg_strategies)
                    drawn = {name: s.example_from(rng)
                             for name, s in strategies.items()}
                    fn(*args, *pos, **dict(kwargs, **drawn))
            # plain (*args, **kwargs) signature on purpose: pytest must not
            # mistake the strategy kwargs for fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    _mod = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.none = _none
    _st.text = _text
    _st.one_of = _one_of
    _st.lists = _lists
    _st.tuples = _tuples
    _mod.given = _given
    _mod.settings = _settings
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
# ---------------------------------------------------------------------------

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

import pytest  # noqa: E402

from repro.configs import get_config  # noqa: E402

ALL_ARCHS = [
    "xlstm-350m", "pixtral-12b", "zamba2-7b", "codeqwen1.5-7b",
    "command-r-plus-104b", "qwen3-14b", "yi-9b", "seamless-m4t-large-v2",
    "deepseek-v2-236b", "mixtral-8x22b",
]


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    return get_config("yi-9b").reduced(n_layers=2, d_model=32, n_heads=2,
                                       n_kv_heads=2, head_dim=16, d_ff=64,
                                       vocab_size=128)


def make_batch(cfg, key, batch=2, seq=16):
    import jax.numpy as jnp  # noqa: F401
    out = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                        cfg.vocab_size)}
    if cfg.frontend == "vision":
        out["frontend"] = jax.random.normal(
            key, (batch, cfg.frontend_len, cfg.d_model))
    if cfg.is_enc_dec:
        out["enc_embeds"] = jax.random.normal(key, (batch, 8, cfg.d_model))
    return out
