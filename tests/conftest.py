import os
import sys

# tests must see the single real CPU device (the dry-run sets its own flags
# in a separate process); keep any user XLA_FLAGS out of the picture.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

import pytest  # noqa: E402

from repro.configs import get_config  # noqa: E402

ALL_ARCHS = [
    "xlstm-350m", "pixtral-12b", "zamba2-7b", "codeqwen1.5-7b",
    "command-r-plus-104b", "qwen3-14b", "yi-9b", "seamless-m4t-large-v2",
    "deepseek-v2-236b", "mixtral-8x22b",
]


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    return get_config("yi-9b").reduced(n_layers=2, d_model=32, n_heads=2,
                                       n_kv_heads=2, head_dim=16, d_ff=64,
                                       vocab_size=128)


def make_batch(cfg, key, batch=2, seq=16):
    import jax.numpy as jnp  # noqa: F401
    out = {"tokens": jax.random.randint(key, (batch, seq), 0,
                                        cfg.vocab_size)}
    if cfg.frontend == "vision":
        out["frontend"] = jax.random.normal(
            key, (batch, cfg.frontend_len, cfg.d_model))
    if cfg.is_enc_dec:
        out["enc_embeds"] = jax.random.normal(key, (batch, 8, cfg.d_model))
    return out
