"""Runtime layer: optimizer math, checkpoint atomicity + elasticity,
lease-driver fault tolerance, gradient compression."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs.base import TrainConfig
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8_ef, decompress_int8, ef_state_init,
                         lr_schedule)
from repro.runtime import driver
from repro.runtime.steps import abstract_train_state


def test_adamw_matches_reference_math():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                     weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([1.0])}
    state = adamw_init(params)
    g = {"w": jnp.array([0.5])}
    new_p, state, _ = adamw_update(params, g, state, tc)
    # step 1: mhat = g, vhat = g^2 -> delta = g/|g| = 1.0 (+eps effects)
    lr1 = lr_schedule(tc, jnp.int32(1))
    expected = 1.0 - float(lr1) * (0.5 / (0.5 + 1e-8))
    assert float(new_p["w"][0]) == pytest.approx(expected, rel=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(3 * 16 + 4 * 9))
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_int8_ef_compression_property(scale, seed):
    """Quantization error is bounded by the step size and fully carried in
    the error-feedback state (lossless across (q + err))."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale}
    ef = ef_state_init(g)
    q, new_ef = compress_int8_ef(g, ef)
    deq = decompress_int8(q)
    step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= step * 0.5 + 1e-6
    # error feedback exactly accounts for the residual
    np.testing.assert_allclose(np.asarray(deq["w"] + new_ef["w"]),
                               np.asarray(g["w"]), atol=1e-5)


def test_checkpoint_atomic_and_gc(tmp_path):
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3))}}
    for step in (1, 2, 3, 4):
        save_checkpoint(tmp_path, step, tree, keep=2)
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(kept) == 2  # GC keeps the last 2
    # torn write (tmp dir without manifest) is never visible
    torn = pathlib.Path(tmp_path) / "step_00000009"
    torn.mkdir()
    assert latest_step(tmp_path) == 4
    out = restore_checkpoint(tmp_path, 4, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(8.0))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, 1, {"a": jnp.zeros((5,))})


def test_driver_preempt_resume_bit_exact(tiny_dense_cfg, tmp_path):
    cfg = tiny_dense_cfg
    tc = TrainConfig(total_steps=12, checkpoint_every=4, warmup_steps=2,
                     learning_rate=1e-3)
    d1, d2 = tmp_path / "a", tmp_path / "b"
    r = driver.train(cfg, tc, workdir=str(d1))
    assert r.status == "finished"
    inj = driver.FailureInjector(at_steps=(6,))
    reps = driver.train_with_restarts(cfg, tc, workdir=str(d2), injector=inj)
    assert [x.status for x in reps] == ["preempted", "finished"]
    ab = abstract_train_state(cfg, tc)
    s1 = restore_checkpoint(d1, latest_step(d1), ab)
    s2 = restore_checkpoint(d2, latest_step(d2), ab)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_driver_lease_chaining(tiny_dense_cfg, tmp_path):
    cfg = tiny_dense_cfg
    tc = TrainConfig(total_steps=6, checkpoint_every=2, lease_seconds=0.4)
    reps = driver.train_with_restarts(cfg, tc, workdir=str(tmp_path),
                                      max_restarts=30)
    assert reps[-1].status == "finished"
    assert reps[-1].end_step == 6
    assert len(reps) >= 2  # at least one lease expiry happened


def test_driver_grad_compression_runs(tiny_dense_cfg, tmp_path):
    tc = TrainConfig(total_steps=3, checkpoint_every=10,
                     grad_compression="int8_ef")
    r = driver.train(tiny_dense_cfg, tc, workdir=str(tmp_path))
    assert r.status == "finished" and np.isfinite(r.metrics[-1]["loss"])


def test_training_reduces_loss(tiny_dense_cfg, tmp_path):
    """A few hundred steps on tiny data: loss must drop substantially."""
    cfg = tiny_dense_cfg
    tc = TrainConfig(total_steps=60, checkpoint_every=1000, warmup_steps=5,
                     learning_rate=3e-3)
    # overfit a single repeated batch -> loss must fall
    from repro.data.synthetic import lm_batch
    fixed = lm_batch(0, 0, 4, 64, cfg.vocab_size)
    r = driver.train(cfg, tc, workdir=str(tmp_path),
                     batch_fn=lambda i: fixed, log_every=1)
    losses = [m["loss"] for m in r.metrics]
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])
