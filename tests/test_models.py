"""Model substrate: per-arch smoke tests + decode-path equivalence +
property tests on attention/MoE invariants."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from conftest import ALL_ARCHS, make_batch
from repro.configs import get_config
from repro.models import lm
from repro.models.attention import _mask_bias, _sdpa_chunked, _sdpa_full
from repro.models.moe import moe_apply, moe_decode, moe_schema
from repro.common import param as pm


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss+grad step, shapes + finite values."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    batch = make_batch(cfg, key)

    def loss(p):
        return lm.loss_fn(p, batch, cfg)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert jnp.isfinite(val)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_forward_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = lm.init(cfg, key)
    batch = make_batch(cfg, key)
    logits, n_prefix, _, _ = lm.forward(params, batch, cfg)
    total = batch["tokens"].shape[1] + n_prefix
    assert logits.shape == (2, total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Serving path == training path, token by token (caches, chaining of
    state through decode, rolling SWA windows, MLA latent cache)."""
    over = {"capacity_factor": 8.0} if get_config(arch).n_experts else {}
    cfg = get_config(arch).reduced(**over)
    key = jax.random.PRNGKey(2)
    params = lm.init(cfg, key)
    S, extra = 12, 3
    batch_full = make_batch(cfg, key, batch=2, seq=S + extra)
    toks = batch_full["tokens"]
    batch_pre = dict(batch_full, tokens=toks[:, :S])
    n_prefix = cfg.frontend_len if cfg.frontend == "vision" else 0

    logits_full, _, _, _ = lm.forward(params, batch_full, cfg)
    logits_p, caches = lm.prefill(params, batch_pre, cfg)
    kv_len = n_prefix + S + extra
    caches = lm._grow_caches(caches, cfg, kv_len)
    errs = [float(jnp.max(jnp.abs(logits_p - logits_full[:, n_prefix + S - 1])))]
    for i in range(extra):
        pos = n_prefix + S + i
        lg, caches = lm.decode_step(params, toks[:, S + i][:, None], pos,
                                    caches, cfg, kv_len=kv_len)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, pos]))))
    assert max(errs) < 1e-4, errs


@given(sq=st.integers(2, 33), kk=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), window=st.sampled_from([0, 5]),
       chunk=st.sampled_from([4, 16]))
@settings(max_examples=12, deadline=None)
def test_chunked_attention_property(sq, kk, g, window, chunk):
    """Chunked (query-block scan) attention == full attention for any
    shape/window/chunking."""
    key = jax.random.PRNGKey(sq * 131 + kk)
    h, d, b = kk * g, 8, 2
    q = jax.random.normal(key, (b, sq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, sq, kk, d))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, sq, kk, d))
    pos = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    full = _sdpa_full(q, k, v, _mask_bias(pos, pos, True, window))
    chk = _sdpa_chunked(q, k, v, pos, pos, True, window, chunk=chunk)
    assert float(jnp.max(jnp.abs(full - chk))) < 1e-5


def test_moe_capacity_semantics():
    """Queue-overflow analogue: tight capacity drops tokens (residual
    carries); generous capacity drops none and matches decode path."""
    cfg = get_config("mixtral-8x22b").reduced(capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    params = pm.init_params(moe_schema(cfg), key, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    y, aux, drop = moe_apply(params, x, cfg)
    assert y.shape == x.shape and float(drop) == 0.0
    y2 = moe_decode(params, x.reshape(16, 1, cfg.d_model), cfg)
    assert float(jnp.max(jnp.abs(y2.reshape(2, 8, -1) - y))) < 1e-4

    # tight capacity at a scale where rounding-to-8 can't hide the cap
    tight = cfg.replace(capacity_factor=0.26)
    x_big = jax.random.normal(key, (2, 64, cfg.d_model))
    yt, _, drop_t = moe_apply(params, x_big, tight)
    assert 0.0 < float(drop_t) <= 1.0
    assert bool(jnp.isfinite(yt).all())


@given(cf=st.floats(0.3, 4.0), seed=st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_moe_drop_fraction_bounded(cf, seed):
    cfg = get_config("deepseek-v2-236b").reduced(capacity_factor=cf)
    key = jax.random.PRNGKey(seed)
    params = pm.init_params(moe_schema(cfg), key, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux, drop = moe_apply(params, x, cfg)
    assert 0.0 <= float(drop) < 1.0
    assert bool(jnp.isfinite(y).all()) and float(aux) >= 0.0


def test_param_schema_consistency():
    """init / abstract / axes trees share structure; axes arity matches."""
    for arch in ("yi-9b", "deepseek-v2-236b", "xlstm-350m"):
        cfg = get_config(arch).reduced()
        schema = lm.lm_schema(cfg)
        abstract = pm.abstract_params(schema, jnp.float32)
        axes = pm.axes_tree(schema)
        flat_a = jax.tree.leaves(abstract)
        flat_x = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
        assert len(flat_a) == len(flat_x)
        for a, x in zip(flat_a, flat_x):
            assert len(a.shape) == len(x)


def test_generate_greedy_deterministic(tiny_dense_cfg):
    cfg = tiny_dense_cfg
    key = jax.random.PRNGKey(4)
    params = lm.init(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size)}
    out1 = lm.generate(params, batch, cfg, n_steps=6)
    out2 = lm.generate(params, batch, cfg, n_steps=6)
    assert out1.shape == (2, 6)
    assert jnp.array_equal(out1, out2)
