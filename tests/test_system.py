"""End-to-end behaviour: the paper's queries on the serverless engine vs
the cluster baseline, and full train/serve loops through the public API."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import FlintConfig, FlintContext
from repro.data.synthetic import GOLDMAN, taxi_csv
from repro.models import lm
from repro.runtime import driver


def _q1(ctx, key="taxi.csv", nparts=6):
    """Paper Q1: drop-offs at Goldman Sachs HQ, aggregated by hour."""
    def inside(row, box=GOLDMAN):
        try:
            lon, lat = float(row[2]), float(row[3])
        except ValueError:
            return False
        return box[0] <= lon <= box[2] and box[1] <= lat <= box[3]

    def get_hour(ts):
        return int(ts[11:13])

    return sorted(ctx.textFile(key, nparts)
                  .map(lambda x: x.split(","))
                  .filter(inside)
                  .map(lambda x: (get_hour(x[1]), 1))
                  .reduceByKey(lambda a, b: a + b, 8)
                  .collect())


def test_q1_flint_equals_cluster():
    data = taxi_csv(3000, seed=3)
    ctx_f = FlintContext("flint", FlintConfig(concurrency=8))
    ctx_c = FlintContext("cluster", FlintConfig(concurrency=8))
    ctx_f.upload("taxi.csv", data)
    ctx_c.upload("taxi.csv", data)
    rf, rc = _q1(ctx_f), _q1(ctx_c)
    assert rf == rc and sum(v for _, v in rf) >= 1
    rep = ctx_f.cost_report()
    # "auto" default: the planner resolves the transport per shuffle
    shuffle_requests = rep["sqs_requests"] + rep["s3_lists"]
    assert rep["total_usd"] > 0 and shuffle_requests > 0


def test_end_to_end_train_and_serve(tmp_path, tiny_dense_cfg):
    """Train a tiny LM through the driver, checkpoint, reload, serve
    batched greedy decode through prefill+decode."""
    cfg = tiny_dense_cfg
    tc = TrainConfig(total_steps=20, checkpoint_every=10, warmup_steps=2)
    rep = driver.train(cfg, tc, workdir=str(tmp_path), verbose=False)
    assert rep.status == "finished"

    from repro.checkpoint import latest_step, restore_checkpoint
    from repro.runtime.steps import abstract_train_state
    state = restore_checkpoint(tmp_path, latest_step(tmp_path),
                               abstract_train_state(cfg, tc))
    prompts = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 8)),
        jnp.int32)}
    out = lm.generate(state.params, prompts, cfg, n_steps=5)
    assert out.shape == (4, 5)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size


def test_serve_prefill_decode_cache_reuse(tiny_dense_cfg):
    """Decode must reuse the prefill cache rather than recompute: logits at
    step k depend on all prior tokens."""
    cfg = tiny_dense_cfg
    params = lm.init(cfg, jax.random.PRNGKey(0))
    t1 = jnp.zeros((1, 8), jnp.int32)
    t2 = t1.at[:, 0].set(5)  # different history
    _, c1 = lm.prefill(params, {"tokens": t1}, cfg)
    _, c2 = lm.prefill(params, {"tokens": t2}, cfg)
    c1 = lm._grow_caches(c1, cfg, 10)
    c2 = lm._grow_caches(c2, cfg, 10)
    tok = jnp.ones((1, 1), jnp.int32)
    l1, _ = lm.decode_step(params, tok, 8, c1, cfg, kv_len=10)
    l2, _ = lm.decode_step(params, tok, 8, c2, cfg, kv_len=10)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6
