"""Columnar record-batch wire format (core.shuffle.batch): typed-array
framing for homogeneous key/value columns, tagged pickle fallback for
ragged data, exact round-trips (concrete types preserved — bool is not
int, 1.0 is not 1), determinism, and the size win that motivates it."""

import pickle

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import serde
from repro.core.costs import CostLedger
from repro.core.queues import ObjectStoreSim, SpillPointer, pack_records
from repro.core.shuffle import (KVBatch, is_columnar, pack_batch,
                                pack_batch_columns, unpack_batch)


def roundtrip(records, **kw):
    bodies = pack_batch(records, **kw)
    out = [r for b in bodies for r in unpack_batch(b)]
    return bodies, out


# ------------------------------------------------------------ happy paths


def test_homogeneous_kv_uses_columnar_framing():
    records = [(f"k{i}", i) for i in range(1000)]
    bodies, out = roundtrip(records)
    assert all(is_columnar(b) for b in bodies)
    assert out == records


def test_taxi_style_tuple_keys_are_columnar():
    records = [((f"{m:02d}", f"{h:02d}", "cash"), 1)
               for m in range(12) for h in range(24)]
    bodies, out = roundtrip(records)
    assert all(is_columnar(b) for b in bodies)
    assert out == records


def test_columnar_shrinks_homogeneous_batches():
    """The acceptance bar: typed columns beat per-record pickles on the
    homogeneous-key workload."""
    records = [((f"{i % 12:02d}", f"{i % 24:02d}", "credit"), i)
               for i in range(5000)]
    columnar = sum(len(b) for b in pack_batch(records, columnar=True))
    pickled = sum(len(b) for b in pack_batch(records, columnar=False))
    assert columnar < pickled * 0.6  # >40% smaller, not a rounding win


def test_columnar_split_under_cap():
    records = [("k" * 100, "v" * 120) for _ in range(5000)]
    bodies = pack_batch(records, limit=64 * 1024)
    assert len(bodies) > 1
    assert all(len(b) <= 64 * 1024 for b in bodies)
    assert [r for b in bodies for r in unpack_batch(b)] == records


def test_pack_batch_is_deterministic():
    records = [((i % 7, f"s{i}"), float(i)) for i in range(500)]
    assert pack_batch(records) == pack_batch(records)
    ragged = [*records, ("odd-one-out", None)]
    assert pack_batch(ragged) == pack_batch(ragged)


# ------------------------------------------------------------- fallbacks


@pytest.mark.parametrize("records", [
    [("a", 1), ("b", "two")],          # ragged value column
    [(1, 1), (1.0, 2)],                # int vs float keys
    [(1, 1), (True, 2)],               # int vs bool keys
    [("k", [1, "two"])],               # mixed-element lists have no schema
    [("k", None)],                     # NoneType has no schema
    [(("a", 1), 1), (("a", 1, 2), 2)],  # mixed tuple arity
    [(2**70, 1)],                      # beyond int64
    ["not-a-pair"],                    # repart-mode records
    [("k", 1, "extra")],               # 3-tuples are not kv pairs
])
def test_ragged_data_falls_back_to_pickle_framing(records):
    bodies, out = roundtrip(records)
    assert not any(is_columnar(b) for b in bodies)
    assert out == records


def test_fallback_matches_legacy_framing_byte_for_byte():
    """The tagged fallback IS the legacy framing plus one tag byte — the
    proven spill/cap behavior is reused, not reimplemented."""
    records = [("k", object.__new__(object).__class__)] * 3  # unschematic
    tagged = pack_batch(records, limit=1024)
    legacy = pack_records(records, limit=1023)
    assert [b[1:] for b in tagged] == legacy


def test_oversized_single_record_spills_via_fallback():
    store = ObjectStoreSim(CostLedger())

    def spill(blob):
        key = "_spill/test"
        store.put(key, blob)
        return key

    big = ("k", "x" * 400_000)
    bodies = pack_batch([("a", 1), big, ("b", 2)], limit=256 * 1024,
                        spill=spill)
    assert all(len(b) <= 256 * 1024 for b in bodies)
    out = [r for b in bodies for r in unpack_batch(b, store)]
    assert out == [("a", 1), big, ("b", 2)]
    ptr_body = pack_batch([big], limit=256 * 1024, spill=spill)[0]
    assert isinstance(pickle.loads(ptr_body[5:]), SpillPointer)


def test_columnar_disabled_forces_pickle_framing():
    records = [(i, i) for i in range(10)]
    bodies = pack_batch(records, columnar=False)
    assert not any(is_columnar(b) for b in bodies)
    assert [r for b in bodies for r in unpack_batch(b)] == records


def test_unknown_tag_rejected():
    with pytest.raises(ValueError, match="unknown batch tag"):
        unpack_batch(b"Zjunk")


# ------------------------------------------------------- property tests

_scalar = st.one_of(
    st.integers(min_value=-2**70, max_value=2**70),
    st.floats(allow_nan=False),
    st.booleans(),
    st.text(max_size=20),
)
_key = st.one_of(_scalar, st.tuples(_scalar, _scalar),
                 st.tuples(_scalar, st.tuples(_scalar, _scalar)))
_value = st.one_of(_scalar, st.none(),
                   st.lists(st.integers(), max_size=3))


@given(st.lists(st.tuples(_key, _value), min_size=1, max_size=60))
@settings(max_examples=120, deadline=None)
def test_mixed_type_roundtrip_property(records):
    """Property: pack/unpack is the identity on ANY mix of data, with
    concrete types preserved exactly (so 1, 1.0 and True stay distinct on
    the wire and only the partitioner canonicalizes)."""
    bodies, out = roundtrip(records)
    assert out == records
    assert [(type(k), type(v)) for k, v in out] \
        == [(type(k), type(v)) for k, v in records]


@given(st.lists(st.tuples(st.text(max_size=8), st.integers(
    min_value=-2**63, max_value=2**63 - 1)), min_size=1, max_size=200))
@settings(max_examples=60, deadline=None)
def test_homogeneous_roundtrip_property(records):
    bodies, out = roundtrip(records)
    assert all(is_columnar(b) for b in bodies)
    assert out == records


@given(st.lists(st.one_of(_scalar, st.tuples(_scalar, _scalar)),
                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_column_codec_roundtrip_property(values):
    schema = serde.column_schema(values)
    if schema is None:
        return  # ragged — the batch layer falls back, nothing to check
    blob = serde.encode_column(schema, values)
    assert serde.decode_column(schema, blob, len(values)) == values
    sizes = serde.column_value_sizes(schema, values)
    assert len(sizes) == len(values)


# ----------------------------------------------------- list-typed columns


def test_list_values_use_columnar_framing():
    """groupByKey output re-shuffled downstream: (key, value-list) records
    now frame as typed columns (the "l(...)" codec) instead of falling
    back to pickle."""
    records = [(i % 4, [j * 3 for j in range(i % 5)]) for i in range(200)]
    bodies, out = roundtrip(records)
    assert all(is_columnar(b) for b in bodies)
    assert out == records


def test_declared_schema_skips_sniffing_and_survives_violation():
    """A plan-declared (key, value) schema packs without per-batch type
    sniffing; records violating the declaration (int64 overflow) fall
    back safely and still round-trip."""
    records = [((i,), (i, float(i))) for i in range(50)]
    bodies, out = roundtrip(records, schema=("t(i)", "t(i,f)"))
    assert all(is_columnar(b) for b in bodies)
    assert out == records
    # identical to what sniffing would produce: same wire bytes
    assert pack_batch(records) == pack_batch(records,
                                             schema=("t(i)", "t(i,f)"))
    overflow = [((1,), (2**70, 0.0))]
    bodies, out = roundtrip(overflow, schema=("t(i)", "t(i,f)"))
    assert out == overflow  # fallback path, still exact


def test_declared_schema_resumes_columnar_after_midstream_fallback():
    """Regression: a violating record mid-stream must not demote the REST
    of the batch to pickles — conforming runs on both sides of it keep
    the declared columnar framing, only the violating run rides a pickle
    frame."""
    good = [((i,), (i, float(i))) for i in range(40)]
    bad = [((98,), (2**70, 0.5)), ((99,), (2**70 + 1, 1.5))]
    records = good[:20] + bad + good[20:]
    bodies, out = roundtrip(records, schema=("t(i)", "t(i,f)"))
    assert out == records
    kinds = [is_columnar(b) for b in bodies]
    assert kinds.count(True) >= 2, kinds   # columnar resumed after the run
    assert kinds.count(False) >= 1, kinds  # the violating run fell back
    # all-conforming tail after an all-violating batch: same story
    bodies2, out2 = roundtrip(bad + good, schema=("t(i)", "t(i,f)"))
    assert out2 == bad + good
    assert is_columnar(bodies2[-1])


def test_kvbatch_column_pack_is_byte_identical_to_row_pack():
    """pack_batch_columns over a KVBatch (the vectorized map side's
    column-major carrier) must produce the SAME wire bytes as pack_batch
    over the equivalent row records — (src, seq) dedup and lineage
    recovery rely on re-emissions being byte-identical regardless of
    which path built the batch."""
    rows = [((i % 5, f"h{i % 3:02d}"), (i, float(i) * 0.5))
            for i in range(500)]
    batch = KVBatch([[r[0][0] for r in rows], [r[0][1] for r in rows]],
                    [[r[1][0] for r in rows], [r[1][1] for r in rows]],
                    "t(i,s)", "t(i,f)")
    assert (pack_batch_columns(batch)
            == pack_batch(rows, schema=("t(i,s)", "t(i,f)")))
    # identical under a tight cap too: same chunk boundaries, same bodies
    assert (pack_batch_columns(batch, limit=4 * 1024)
            == pack_batch(rows, limit=4 * 1024,
                          schema=("t(i,s)", "t(i,f)")))


def test_kvbatch_nonconforming_falls_back_like_rows():
    """A KVBatch whose columns violate the declared schema (int64
    overflow) packs exactly as the row path would: declared runs split,
    everything round-trips."""
    rows = [((i,), (i if i != 3 else 2**70, float(i))) for i in range(8)]
    batch = KVBatch([[r[0][0] for r in rows]],
                    [[r[1][0] for r in rows], [r[1][1] for r in rows]],
                    "t(i)", "t(i,f)")
    got = pack_batch_columns(batch)
    assert got == pack_batch(rows, schema=("t(i)", "t(i,f)"))
    assert [r for b in got for r in unpack_batch(b)] == rows


@given(st.lists(st.tuples(
    st.integers(min_value=0, max_value=9),
    st.lists(st.one_of(st.integers(min_value=-2**31, max_value=2**31),
                       st.text(max_size=6)), max_size=6)),
    min_size=1, max_size=40))
@settings(max_examples=120, deadline=None)
def test_ragged_list_roundtrip_property(records):
    """Property: ragged lists (mixed lengths, empty lists, int or str
    elements, mixed across records) always round-trip exactly — columnar
    when the flattened elements are homogeneous, pickle fallback when
    not."""
    bodies, out = roundtrip(records)
    assert out == records
    assert [type(v) for _, v in out] == [list] * len(records)


@given(st.lists(st.lists(st.lists(st.integers(min_value=0, max_value=99),
                                  max_size=4), max_size=3),
                min_size=1, max_size=20))
@settings(max_examples=60, deadline=None)
def test_nested_list_column_codec_property(values):
    schema = serde.column_schema(values)
    if schema is None:
        return
    assert schema in ("l()", "l(l())", "l(l(i))")
    blob = serde.encode_column(schema, values)
    assert serde.decode_column(schema, blob, len(values)) == values
    sizes = serde.column_value_sizes(schema, values)
    assert len(sizes) == len(values)
