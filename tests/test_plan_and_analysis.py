"""Coverage for the planning + analysis layers: DAG stage cutting, the
mini-cloudpickle, the loop-aware HLO cost model, and dry-run input specs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.core import FlintConfig, FlintContext, build_plan
from repro.core import serde
from repro.core.dag import ShuffleRead, SourceInput
from repro.launch import hlo_cost


# ----------------------------------------------------------------- DAG


def _ctx():
    ctx = FlintContext("flint", FlintConfig(concurrency=2))
    ctx.upload("t.txt", b"a\nb\nc\n" * 50)
    return ctx


def test_narrow_chain_is_single_stage():
    ctx = _ctx()
    rdd = ctx.textFile("t.txt", 3).map(str.upper).filter(lambda x: True)
    stages = build_plan(rdd, "collect")
    assert len(stages) == 1
    assert len(stages[0].tasks) == 3
    assert all(isinstance(t.input, SourceInput) for t in stages[0].tasks)
    assert [k for k, _ in stages[0].tasks[0].ops] == ["map", "filter"]


def test_wide_dep_cuts_stage():
    ctx = _ctx()
    rdd = (ctx.textFile("t.txt", 3).map(lambda x: (x, 1))
           .reduceByKey(lambda a, b: a + b, 4).map(lambda kv: kv[0]))
    stages = build_plan(rdd, "collect")
    assert len(stages) == 2
    assert stages[0].write is not None and stages[0].write.mode == "agg"
    assert len(stages[1].tasks) == 4  # one per shuffle partition
    assert isinstance(stages[1].tasks[0].input, ShuffleRead)
    assert [k for k, _ in stages[1].tasks[0].ops] == ["map"]


def test_join_produces_two_producer_stages():
    ctx = _ctx()
    left = ctx.parallelize([(1, "a")], 2)
    right = ctx.parallelize([(1, "b")], 2)
    stages = build_plan(left.join(right, 3), "collect")
    assert len(stages) == 3  # left write, right write, join read
    assert stages[0].write.key_side == "left"
    assert stages[1].write.key_side == "right"
    assert len(stages[2].tasks[0].input.parts) == 2


def test_partition_multiplier_scales_wide_ops():
    ctx = _ctx()
    rdd = ctx.textFile("t.txt", 2).map(lambda x: (x, 1)).groupByKey(3)
    stages = build_plan(rdd, "collect", partition_multiplier=4)
    assert stages[0].write.nparts == 12
    assert len(stages[1].tasks) == 12


def test_union_and_mappartitions():
    ctx = _ctx()
    a = ctx.parallelize(list(range(10)), 2)
    b = ctx.parallelize(list(range(10, 20)), 3)
    u = a.union(b).mapPartitions(lambda it: [sum(it)])
    out = u.collect()
    assert len(out) == 5 and sum(out) == sum(range(20))
    assert a.union(b).count() == 20


# --------------------------------------------------------------- serde


def test_serde_nested_closures():
    def outer(k):
        def inner(x):
            return x + k
        return inner

    fn = outer(5)
    assert serde.loads_fn(serde.dumps_fn(fn))(3) == 8


def test_serde_recursive_global_function():
    import math

    def helper(x):
        return math.floor(x) + 1

    def top(x):
        return helper(x) * 2

    assert serde.loads_fn(serde.dumps_fn(top))(3.7) == 8


def test_serde_plain_builtin():
    import operator
    assert serde.loads_fn(serde.dumps_fn(operator.add))(2, 3) == 5


# ------------------------------------------------------------ hlo_cost


def test_hlo_cost_scan_multiplier_exact():
    w = jnp.zeros((7, 64, 128), jnp.float32)
    x0 = jnp.zeros((32, 64))

    def step(x, wi):
        return (x @ wi) @ wi.T, None

    txt = jax.jit(lambda x, w: jax.lax.scan(step, x, w)[0]) \
        .lower(x0, w).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 7 * 2 * (2 * 32 * 64 * 128)


def test_hlo_cost_nested_scan_multiplies():
    w = jnp.zeros((3, 16, 16), jnp.float32)

    def inner(x, wi):
        return x @ wi, None

    def outer(x, _):
        return jax.lax.scan(inner, x, w)[0], None

    fn = jax.jit(lambda x: jax.lax.scan(outer, x, jnp.arange(5))[0])
    txt = fn.lower(jnp.zeros((16, 16))).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 5 * 3 * (2 * 16 * 16 * 16)


def test_hlo_cost_counts_dot_without_loops():
    fn = jax.jit(lambda a, b: a @ b)
    txt = fn.lower(jnp.zeros((8, 16)), jnp.zeros((16, 4))).compile().as_text()
    res = hlo_cost.analyze(txt)
    assert res["flops"] == 2 * 8 * 16 * 4
    assert res["collective_total"] == 0


# ------------------------------------------------------------ input specs


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-236b", "xlstm-350m",
                                  "seamless-m4t-large-v2"])
def test_dryrun_cell_shapes_are_abstract(arch):
    """dryrun_cell must produce pure ShapeDtypeStructs (no allocation)."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import dryrun_cell
    mesh = make_host_mesh(data=1, model=1)
    for shape in ("train_4k", "decode_32k"):
        cfg = get_config(arch)
        if SHAPES[shape].kind == "decode" and not (cfg.subquadratic
                                                   or shape == "decode_32k"):
            continue
        step, args, donate, jkw = dryrun_cell(arch, shape, mesh)
        leaves = jax.tree.leaves(args)
        assert leaves, arch
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        assert callable(step) and "out_shardings" in jkw


def test_shape_table_matches_assignment():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288
