"""Transport-conformance suite: every ShuffleTransport backend must honor
the same contract (docs/shuffle_transports.md) — EOS quorum termination
(including under producer chaining), recoverable consumer death mid-drain,
idempotent duplicate/redelivery absorption, byte-identical retry
re-emission, fast abort of losing competitors on a released partition, and
zero leaked channels/keys after job-end GC. Parametrized over both
backends so a new transport only has to pass this file to be trusted."""

import operator

import pytest

from repro.core import FlintConfig, FlintContext
from repro.core.costs import CostLedger
from repro.core.dag import ShuffleRead, build_plan
from repro.core.executors import FlintConfig as FC, LambdaSim, _drain_shuffle
from repro.core.queues import ObjectStoreSim, SQSSim
from repro.core.shuffle import (AbortedError, TransportSet, pack_batch,
                                transport_names, unpack_batch)

BACKENDS = ["sqs", "s3"]

TEXT = "\n".join(["the quick brown fox", "jumps over the lazy dog",
                  "the dog barks"] * 100).encode()

EXPECTED = {"the": 300, "quick": 100, "brown": 100, "fox": 100,
            "jumps": 100, "over": 100, "lazy": 100, "dog": 200, "barks": 100}


def wordcount(ctx, nparts=4, red_parts=3):
    ctx.upload("text.txt", TEXT)
    return dict(ctx.textFile("text.txt", nparts)
                .flatMap(lambda line: line.split())
                .map(lambda w: (w, 1))
                .reduceByKey(operator.add, red_parts)
                .collect())


def make_env(backend, **cfg_kw):
    cfg_kw = {"visibility_timeout_s": 0.3, "drain_timeout_s": 5.0, **cfg_kw}
    cfg = FC(shuffle_backend=backend, **cfg_kw)
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    sqs = SQSSim(ledger, visibility_timeout=cfg.visibility_timeout_s)
    env = LambdaSim(cfg, ledger, store, sqs)
    return env, env.transports.get(backend)


def ship(tr, sid, nparts, src, per_part_records):
    """Producer-side helper: pack, send, close the stream."""
    totals = {}
    for p, records in per_part_records.items():
        bodies = pack_batch(records, limit=tr.batch_limit, spill=tr.spill)
        tr.send(sid, p, src, 0, bodies)
        totals[p] = len(bodies)
    tr.emit_eos(sid, nparts, src, totals)
    return totals


def drain_all(tr, sid, partition, quorum):
    handle = tr.open_drain(sid, partition, quorum)
    got = [(src, seq, unpack_batch(body, tr.store))
           for src, seq, body in handle]
    return got, handle


# ------------------------------------------------------------ end to end


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("pipelined", [True, False])
def test_wordcount_end_to_end(backend, pipelined):
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            shuffle_backend=backend,
                                            pipeline_stages=pipelined))
    assert wordcount(ctx) == EXPECTED


@pytest.mark.parametrize("backend", BACKENDS)
def test_eos_under_chaining(backend):
    """A chained producer must not emit EOS until its last link; consumers
    still terminate with the full record set on every transport."""
    ctx = FlintContext("flint", FlintConfig(concurrency=4,
                                            shuffle_backend=backend,
                                            max_records_per_invoke=35,
                                            flush_records=10))
    assert wordcount(ctx) == EXPECTED
    assert ctx.last_scheduler.stage_stats[0]["chained"] > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_consumer_failure_recovers(backend):
    """A consumer dying mid-task completes via retry with identical
    results on every transport (SQS: unacked claims redeliver after the
    visibility deadline; S3: non-destructive reads re-list)."""
    cfg = dict(concurrency=4, flush_records=20, shuffle_backend=backend,
               visibility_timeout_s=0.5, drain_timeout_s=8.0)
    clean = wordcount(FlintContext("flint", FlintConfig(**cfg)))
    faulty = FlintContext("flint", FlintConfig(**cfg),
                          fault_plan={(1, 0): {"fail_after_records": 1}},
                          elastic_retries=0)
    assert wordcount(faulty) == clean == EXPECTED
    # the fault actually fired: the dead consumer was retried
    assert faulty.last_scheduler.stage_stats[-1]["attempts"] >= 4


@pytest.mark.parametrize("backend", BACKENDS)
def test_join_and_groupby_per_transport(backend):
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            shuffle_backend=backend))
    left = ctx.parallelize([(i % 5, f"L{i}") for i in range(20)], 3)
    right = ctx.parallelize([(i % 5, f"R{i}") for i in range(10)], 2)
    assert len(left.join(right, 4).collect()) == 40
    grouped = dict(ctx.parallelize([(i % 3, i) for i in range(12)], 2)
                   .groupByKey(3).collect())
    assert sorted(grouped[0]) == [0, 3, 6, 9]


# ------------------------------------------------- transport-level contract


@pytest.mark.parametrize("backend", BACKENDS)
def test_consumer_death_mid_drain_recovers(backend):
    """A drain that consumed everything but never acked leaves the input
    recoverable: a fresh drain of the same partition sees the identical
    batch set (after the visibility deadline lapses, on lease-based
    transports)."""
    env, tr = make_env(backend)
    tr.open(5, 1)
    ship(tr, 5, 1, "s0t0", {0: [("a", 1), ("b", 2)]})
    first, h1 = drain_all(tr, 5, 0, quorum=1)
    # first attempt "dies" here: h1.ack() never called
    import time
    time.sleep(0.4)  # let SQS claims lapse; no-op for S3
    second, h2 = drain_all(tr, 5, 0, quorum=1)
    assert first == second and len(first) == 1
    h2.ack()


@pytest.mark.parametrize("backend", BACKENDS)
def test_byte_identical_retry_reemission_dedups(backend):
    """A retry (or speculative twin) re-sends the SAME (src, seq) bodies
    and a second EOS; one drain must fold each batch exactly once."""
    env, tr = make_env(backend)
    tr.open(6, 1)
    records = [(f"k{i}", i) for i in range(40)]
    ship(tr, 6, 1, "s0t0", {0: records})
    ship(tr, 6, 1, "s0t0", {0: records})  # byte-identical re-emission
    got, handle = drain_all(tr, 6, 0, quorum=1)
    assert [r for _, _, recs in got for r in recs] == records
    if backend == "s3":
        # content-addressed keys: the re-emission overwrote, not duplicated
        assert len([k for k in tr.store.list("_exchange/6/p0/")
                    if "eos" not in k]) == 1
    handle.ack()


@pytest.mark.parametrize("backend", BACKENDS)
def test_eos_on_empty_partition_terminates(backend):
    """Producers close EVERY partition (total 0 where they wrote nothing);
    a drain of an untouched partition terminates empty instead of hanging."""
    env, tr = make_env(backend)
    tr.open(7, 2)
    ship(tr, 7, 2, "s0t0", {0: [("only", 1)]})  # partition 1 never written
    got, handle = drain_all(tr, 7, 1, quorum=1)
    assert got == []
    handle.ack()


@pytest.mark.parametrize("backend", BACKENDS)
def test_released_partition_aborts_competing_drain(backend):
    """After a winner completes and its partition is released, a competing
    drain must abort fast (QueueGone / exchange tombstone) instead of
    waiting out the drain timeout."""
    env, tr = make_env(backend)
    tr.open(8, 1)
    ship(tr, 8, 1, "s0t0", {0: [("a", 1)]})
    tr.release_partition(8, 0)
    with pytest.raises(AbortedError):
        drain_all(tr, 8, 0, quorum=1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_incomplete_stream_times_out(backend):
    """No EOS ever (stuck producer): the inactivity deadline must fire."""
    env, tr = make_env(backend, drain_timeout_s=0.5)
    tr.open(9, 1)
    bodies = pack_batch([("a", 1)])
    tr.send(9, 0, "s0t0", 0, bodies)  # data but never an EOS
    with pytest.raises(TimeoutError):
        drain_all(tr, 9, 0, quorum=1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_gc_sweeps_channels(backend):
    env, tr = make_env(backend)
    tr.open(10, 2)
    ship(tr, 10, 2, "s0t0", {0: [("a", 1)], 1: [("b", 2)]})
    tr.gc()
    assert not env.store.list("_exchange/")
    if backend == "sqs":
        assert env.sqs._queues == {}


# ------------------------------------------------- multi-consumer fan-out


@pytest.mark.parametrize("backend", BACKENDS)
def test_two_consumer_groups_each_get_full_stream(backend):
    """A CSE-shared shuffle with two consumer groups: each group's drain
    sees the COMPLETE stream independently (SQS materializes per-group
    queue sets at emit; S3 objects are simply read twice)."""
    env, tr = make_env(backend)
    tr.open(11, 1, groups=2)
    records = [(f"k{i}", i) for i in range(10)]
    ship(tr, 11, 1, "s0t0", {0: records})
    for g in (0, 1):
        handle = tr.open_drain(11, 0, 1, consumer_group=g)
        got = [r for _, _, body in handle
               for r in unpack_batch(body, tr.store)]
        assert got == records, f"group {g} saw a partial stream"
        handle.ack()


@pytest.mark.parametrize("backend", BACKENDS)
def test_group_release_only_aborts_own_group(backend):
    """release_partition is per consumer group: the released group's
    competing drain aborts fast, the sibling group keeps draining."""
    env, tr = make_env(backend)
    tr.open(12, 1, groups=2)
    ship(tr, 12, 1, "s0t0", {0: [("a", 1)]})
    tr.release_partition(12, 0, consumer_group=0)
    with pytest.raises(AbortedError):
        drain_all(tr, 12, 0, quorum=1)  # group 0's loser twin
    handle = tr.open_drain(12, 0, 1, consumer_group=1)
    got = [r for _, _, body in handle for r in unpack_batch(body, tr.store)]
    assert got == [("a", 1)]
    handle.ack()


@pytest.mark.parametrize("backend", BACKENDS)
def test_data_reclaimed_only_after_every_group_released(backend):
    """The shuffle's bytes live until the LAST consumer group releases."""
    env, tr = make_env(backend)
    tr.open(13, 1, groups=2)
    ship(tr, 13, 1, "s0t0", {0: [("a", 1)]})
    tr.release_partition(13, 0, consumer_group=0)
    if backend == "s3":
        assert any("eos" not in k and ".released" not in k
                   for k in env.store.list("_exchange/13/p0/")), \
            "data vanished while group 1 still owed a drain"
    else:
        assert any(n.endswith("g1-p0") for n in env.sqs._queues)
    tr.release_partition(13, 0, consumer_group=1)
    if backend == "s3":
        assert not any("eos" in k for k in env.store.list("_exchange/13/"))
        assert all(".released" in k[len("_exchange/13/p0/"):]
                   for k in env.store.list("_exchange/13/p0/"))
    else:
        assert not any(n.startswith("shuffle13-") for n in env.sqs._queues)


def test_fanout_enqueues_independent_message_objects_per_group():
    """The SQS sim enqueues caller objects directly and Message.receipt
    is a mutable per-receive slot — fan-out must therefore give every
    group queue its OWN Message copies, or concurrent sibling-group
    receives
    clobber each other's receipt handles (acks/heartbeats go stale)."""
    from repro.core.shuffle import queue_name
    env, tr = make_env("sqs")
    tr.open(15, 1, groups=2)
    ship(tr, 15, 1, "s0t0", {0: [("a", 1)]})
    m0 = [m for m in env.sqs.receive_many(queue_name(15, 0, 0), 10)
          if m.kind == "data"]
    m1 = [m for m in env.sqs.receive_many(queue_name(15, 0, 1), 10)
          if m.kind == "data"]
    assert m0 and m1 and m0[0] is not m1[0], \
        "groups share one Message object — receipts will clobber"
    assert m0[0].receipt is not None and m0[0].receipt != m1[0].receipt


def test_batched_discovery_one_list_serves_all_partitions():
    """S3 exchange discovery is batched at the shuffle level: draining a
    4-partition fan-in costs ~one LIST, not one per partition (ROADMAP
    item; the request-count drop is the point)."""
    env, tr = make_env("s3")
    tr.open(14, 4)
    ship(tr, 14, 4, "s0t0", {p: [(f"k{p}", p)] for p in range(4)})
    lists_before = env.ledger.s3_lists
    for p in range(4):
        got, handle = drain_all(tr, 14, p, quorum=1)
        assert [r for _, _, recs in got for r in recs] == [(f"k{p}", p)]
        handle.ack()
    lists_used = env.ledger.s3_lists - lists_before
    # first drain's LIST discovers every partition's keys; the second may
    # re-LIST before the shared backoff kicks in; the rest ride the index
    assert lists_used <= 2, \
        f"{lists_used} LISTs for 4 partitions — discovery not batched"


# --------------------------------------------------- scheduler integration


def test_mixed_transports_in_one_query():
    """Per-shuffle transport hints (Flock-style): one query, first shuffle
    over the S3 exchange, second over SQS queues."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            shuffle_backend="sqs"))
    ctx.upload("text.txt", TEXT)
    out = dict(ctx.textFile("text.txt", 4)
               .flatMap(lambda line: line.split())
               .map(lambda w: (w, 1))
               .reduceByKey(operator.add, 3, transport="s3")
               .map(lambda kv: (kv[1], 1))
               .reduceByKey(operator.add, 2)
               .collect())
    assert out == {100: 7, 200: 1, 300: 1}
    rep = ctx.cost_report()
    assert rep["s3_lists"] > 0       # the exchange's polling discovery ran
    assert rep["sqs_requests"] > 0   # and so did the queue transport
    # GC swept the exchange tree (tombstones included)
    assert not ctx.store.list("_exchange/")


def test_plan_carries_transport_hint():
    ctx = FlintContext("flint", FlintConfig(concurrency=2))
    rdd = (ctx.parallelize([(1, 1)], 1)
           .reduceByKey(operator.add, 2, transport="s3"))
    stages = build_plan(rdd, "collect")
    assert stages[0].write.transport == "s3"
    read = stages[1].tasks[0].input
    assert isinstance(read, ShuffleRead)
    assert read.transports == {stages[0].write.shuffle_id: "s3"}


def test_unknown_transport_name_rejected():
    ledger = CostLedger()
    ts = TransportSet(FC(), ledger, ObjectStoreSim(ledger), SQSSim(ledger))
    with pytest.raises(ValueError, match="unknown shuffle transport"):
        ts.get("carrier-pigeon")
    assert transport_names() == ["s3", "sqs"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_no_transient_keys_survive_query(backend):
    """The acceptance bar: a completed query leaves zero _spill/, _payload/
    or _exchange/ keys and no queues behind."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            shuffle_backend=backend,
                                            flush_records=20))
    assert wordcount(ctx) == EXPECTED
    for prefix in ("_spill/", "_payload/", "_exchange/", "_result/",
                   "_stream/"):
        assert not ctx.store.list(prefix), f"leaked {prefix} keys"
    assert ctx.last_scheduler.sqs._queues == {}


@pytest.mark.parametrize("backend", BACKENDS)
def test_barrier_mode_shares_eos_termination(backend):
    """pipeline_stages=False still works on every transport — through the
    same EOS quorum path (the expectation-table handover is gone)."""
    ctx = FlintContext("flint", FlintConfig(concurrency=8,
                                            shuffle_backend=backend,
                                            pipeline_stages=False))
    assert wordcount(ctx) == EXPECTED


def test_multipart_billing_distinct_from_put():
    """An exchange object past the multipart threshold bills Create +
    UploadParts + Complete, tracked apart from plain PUTs."""
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    store.put("small", b"x" * 1024)
    assert (ledger.s3_puts, ledger.s3_upload_parts) == (1, 0)
    store.put("big", b"x" * (20 * 2**20))  # 20 MiB: 3 parts of 8 MiB
    assert ledger.s3_puts == 3  # +Create +Complete
    assert ledger.s3_upload_parts == 3
    sub = ledger.service_subtotals()
    assert sub["s3.UploadPart"] > 0 and sub["s3.PUT"] > 0


def test_list_requests_billed():
    ledger = CostLedger()
    store = ObjectStoreSim(ledger)
    store.put("a/1", b"x")
    store.list("a/")
    assert ledger.s3_lists == 1
    assert ledger.service_subtotals()["s3.LIST"] > 0
