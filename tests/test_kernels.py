"""Per-kernel interpret=True sweeps against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kk,sq,skv,d,causal,window",
    [
        (2, 4, 2, 128, 128, 64, True, 0),     # GQA causal prefill
        (1, 2, 2, 256, 256, 32, True, 64),    # sliding window
        (2, 4, 4, 128, 128, 16, False, 0),    # MHA bidirectional (encoder)
        (1, 8, 2, 128, 384, 64, True, 0),     # decode-style, Sq < Skv
        (1, 2, 1, 64, 64, 128, True, 0),      # MQA
    ])
def test_flash_attention_sweep(b, h, kk, sq, skv, d, causal, window, dtype):
    key = jax.random.PRNGKey(b * 7 + h)
    q = jax.random.normal(key, (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, kk, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, kk, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = ref.flash_attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32),
                                  causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


@given(n=st.integers(1, 700), p=st.sampled_from([4, 16, 33]),
       d=st.sampled_from([8, 64]))
@settings(max_examples=10, deadline=None)
def test_bucket_reduce_property(n, p, d):
    """Per-bucket sums == oracle; total mass preserved (nothing lost in
    the 'shuffle')."""
    key = jax.random.PRNGKey(n)
    vals = jax.random.normal(key, (n, d), jnp.float32)
    ids = jax.random.randint(key, (n,), 0, p)
    out = ops.bucket_reduce(vals, ids, p)
    exp = ref.bucket_reduce_ref(vals, ids, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)
    np.testing.assert_allclose(np.asarray(out.sum(0)),
                               np.asarray(vals.sum(0)), atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,t,d,f", [(4, 64, 32, 48), (2, 128, 128, 128),
                                     (8, 16, 64, 8), (1, 256, 512, 128)])
def test_grouped_matmul_sweep(e, t, d, f, dtype):
    key = jax.random.PRNGKey(e)
    x = jax.random.normal(key, (e, t, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(9), (e, d, f), dtype)
    out = ops.grouped_matmul(x, w)
    exp = ref.grouped_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol * d, rtol=tol)


def test_flash_attention_grad_flows():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32))
    g = jax.grad(lambda q: ops.flash_attention(q, k, v).sum())(q)
    assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0
